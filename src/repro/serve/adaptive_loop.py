"""Closed-loop two-timescale adaptation under traffic drift (DESIGN.md §13).

The paper's Eqs. 17–18 describe a fast path that only ever *reads* compiled
tables and a slow control plane that periodically re-learns and atomically
re-installs them.  Earlier layers built every piece — EMA statistics
(:mod:`repro.core.two_timescale`), audited deltas
(:func:`repro.compile.program.compile_delta`), measured atomic installs
(``FlowEngine.swap_tables``) — but nothing *drove* them: no runtime ever
decided **when** to recompile.  :class:`AdaptiveLoop` closes that loop:

* **Drift detection (fast timescale, on-device).**  Every ingest batch
  updates two-rate EWMAs — per-class trust-score histograms, class mix,
  veto rate, flow churn, and packed-signature marker-bit frequencies —
  through one jitted summarize/commit pair over fixed lane shapes, so the
  drift path never retraces no matter how batch sizes vary.
* **Drift policy (host).**  :class:`DriftPolicy` thresholds the
  fast-vs-slow EWMA distances (total variation on the class mix, per-class
  histogram TV, veto/churn shifts, signature novelty) with warmup and
  cooldown, and decides when the control plane wakes up.
* **Adaptation (slow timescale).**  A fired policy runs
  ``TwoTimescaleController.maybe_recluster`` (harvested per-flow pooled
  features → weighted k-means → Eq. 20 churn gate) →
  ``compile_delta`` (re-audited tables; a relearn hook may resynthesize
  the TCAM tier from :func:`repro.core.two_timescale
  .novel_signature_bits`) → ``swap_tables(delta=)``.  In async mode the
  recluster+compile work runs on a background thread and the finished
  delta is installed at the next tick boundary, so fast-path ingest is
  never blocked; sync mode runs the whole chain inline at the triggering
  tick (deterministic — what the differential conformance tier replays).
* **Accounting.**  Every install is measured end-to-end and held to the
  Eq. 18 ``t_cp`` budget — a violating install is *rolled back* (the
  previous tables are atomically re-installed), and a delta that no longer
  fits the budget (``BudgetError`` from the compile passes) is never
  installed at all.  Each adaptation appends an :class:`AdaptationRecord`
  (trigger stats, recluster verdict, delta ledger diff, install timing,
  rollback flags) to :attr:`AdaptiveLoop.history`.

Works over either serving runtime — :class:`~repro.serve.flow_engine
.FlowEngine` or the sharded :class:`~repro.serve.sharded_flow_engine
.ShardedFlowEngine` — any engine deployed from a
:class:`~repro.compile.program.DataplaneProgram` (deltas recompile against
the installed program).
"""

from __future__ import annotations

import dataclasses
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compile.ledger import BudgetError
from repro.core import hardware_model
from repro.core import symbolic
from repro.core import two_timescale as TT

_METRIC_NAMES = (
    "class_dist", "hist_dist", "veto_shift", "churn_shift", "sig_novelty",
)


@dataclasses.dataclass(frozen=True)
class DriftPolicy:
    """When does the control plane wake up?  Each field thresholds one
    drift metric from :func:`repro.core.two_timescale.drift_metrics`
    (0 disables that detector).  ``warmup_ticks`` suppresses triggers until
    the EWMAs have content; ``cooldown_ticks`` is the minimum spacing
    between control-plane epochs (the serving-side T_cp floor)."""

    class_dist: float = 0.12  # total variation on the predicted-class mix
    hist_dist: float = 0.0  # per-class trust-histogram TV (mass-weighted)
    veto_shift: float = 0.0  # |fast - slow| veto rate
    churn_shift: float = 0.15  # |fast - slow| new-flow fraction
    sig_novelty: float = 0.07  # max marker-bit frequency surge over baseline
    warmup_ticks: int = 3
    cooldown_ticks: int = 6

    def fired(self, metrics: Dict[str, float]) -> Tuple[str, ...]:
        """Names of the detectors whose thresholds ``metrics`` crossed."""
        return tuple(
            name for name in _METRIC_NAMES
            if getattr(self, name) > 0 and metrics[name] >= getattr(self, name)
        )


@dataclasses.dataclass(frozen=True)
class AdaptiveLoopConfig:
    eta_fast: float = 0.25  # recent-window EWMA rate (memory ~4 batches)
    eta_slow: float = 0.02  # baseline EWMA rate (memory ~50 batches)
    n_bins: int = 8  # trust-score histogram bins
    stats_lanes: int = 256  # fixed drift-summary lane width (jit shape)
    sync: bool = True  # inline control plane; False = background thread
    observe_cap: int = 32  # resident flows sampled into the reservoir/tick
    novelty_bit_threshold: float = 0.05  # relearn: marker-bit surge floor
    relearn_veto_floor: float = 0.06  # relearn only while the TCAM is blind
    t_cp_s: float = 0.0  # Eq. 18 install budget; 0 → engine's, else 60s


@dataclasses.dataclass
class AdaptationRecord:
    """One control-plane epoch, end to end: why it fired, what the
    recluster decided, what the delta cost, how the install went."""

    tick: int  # engine tick the policy fired on
    trigger: Dict[str, float]  # drift metrics at fire time
    fired_on: Tuple[str, ...]  # which DriftPolicy detectors crossed
    installed: bool
    rolled_back: bool = False  # install exceeded t_cp and was undone
    error: Optional[str] = None  # BudgetError text / hold reason
    install_tick: int = 0  # engine tick the install landed on (async ≥ tick)
    install_s: float = 0.0  # measured wall-clock install (Eq. 18)
    t_cp_s: float = 0.0  # the budget the install was held to
    churn_ok: bool = True  # Eq. 18 verdict
    delta_step: int = 0  # control-plane epoch counter
    recluster: Optional[Dict[str, Any]] = None  # InstallRecord fields
    ledger_diff: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict
    )  # program ledger vs delta ledger, per stage/resource

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["fired_on"] = list(self.fired_on)
        return d


def default_relearn(
    loop: "AdaptiveLoop", trigger: Dict[str, float], fired_on: Tuple[str, ...]
) -> Dict[str, Any]:
    """Control-plane rule resynthesis from streaming novelty.

    If the signature-novelty detector names marker bits surging above the
    long-run baseline (an adversarial signature the installed TCAM tier has
    never seen), rebuild every hard-rule row as an exact-match conjunction
    over the hottest novel bits — those within 2x of the strongest surge,
    capped at the 4-token anomaly-signature width, so a duplicate-token
    signature (3 distinct bits) never drags a spurious weak bit into the
    conjunction.  The rebuilt rows keep the installed RuleSet's shape, so
    the delta's shape check passes and the jitted hot path is reused
    verbatim.  With no novel bits the tables are left as-is (the delta
    still re-audits and re-installs the current weights).

    Resynthesis is gated on *veto coverage*: while the installed rules are
    still firing (recent veto rate above ``relearn_veto_floor``) the TCAM
    tier is not blind, and a trigger driven by churn or class drift must
    not overwrite a working signature with phase-boundary transients — the
    delta then simply re-audits and re-installs the current tables.

    Deterministic given the drift statistics, which are themselves
    deterministic functions of the replayed traffic — so conformance
    replays re-derive identical rules on every engine.
    """
    stats = loop.trigger_stats  # snapshot from the firing tick, not live
    veto_f, _ = TT._debiased(stats, loop.scfg, "veto")
    if float(veto_f) > loop.cfg.relearn_veto_floor:
        return {}
    mask = np.asarray(TT.novel_signature_bits(
        loop.scfg, stats, loop.cfg.novelty_bit_threshold
    ))
    if not mask.any():
        return {}
    sig_f, sig_s = TT._debiased(stats, loop.scfg, "sig")
    strength = np.asarray(sig_f - sig_s)
    novel = np.nonzero(mask)[0]
    novel = novel[np.argsort(-strength[novel], kind="stable")]
    novel = novel[strength[novel] >= 0.5 * strength[novel[0]]][:4]
    rules = loop.engine.rules
    rows = np.nonzero(np.asarray(rules.hard))[0]
    if rows.size == 0:
        # nothing to resynthesize: overwriting a soft row would destroy an
        # HL-MRF rule without ever producing a veto
        return {}
    vals = np.asarray(rules.values).copy()
    masks = np.asarray(rules.masks).copy()
    word = np.zeros((vals.shape[1],), np.uint32)
    for b in novel.tolist():
        word[b // 32] |= np.uint32(1) << np.uint32(b % 32)
    for r in rows.tolist():
        vals[r] = word
        masks[r] = word
    return {
        "ruleset": symbolic.RuleSet(
            values=jnp.asarray(vals),
            masks=jnp.asarray(masks),
            weights=jnp.asarray(np.asarray(rules.weights)),
            hard=jnp.asarray(np.asarray(rules.hard)),
        )
    }


class AdaptiveLoop:
    """Drive a flow-serving engine through non-stationary traffic, closing
    the drift-detect → recompile → atomic-install loop (§3.6).

    ``relearn(loop, trigger, fired_on) -> {"ruleset": ..., "new_weights":
    ...}`` lets deployments plug in their own slow-path learner; the
    default resynthesizes hard rules from signature novelty.
    """

    def __init__(
        self,
        engine,
        policy: Optional[DriftPolicy] = None,
        cfg: Optional[AdaptiveLoopConfig] = None,
        controller: Optional[TT.TwoTimescaleController] = None,
        relearn: Optional[Callable] = None,
    ):
        if getattr(engine, "program", None) is None:
            raise ValueError(
                "AdaptiveLoop needs a program-deployed engine "
                "(program.deploy(DeploySpec(...))): slow-timescale deltas "
                "recompile against the installed program"
            )
        self.engine = engine
        self.policy = policy if policy is not None else DriftPolicy()
        self.cfg = cfg if cfg is not None else AdaptiveLoopConfig()
        ccfg = engine.ccfg
        self.scfg = TT.DriftStatsConfig(
            n_classes=ccfg.n_classes,
            n_bins=self.cfg.n_bins,
            n_bits=32 * ccfg.sig_words,
            eta_fast=self.cfg.eta_fast,
            eta_slow=self.cfg.eta_slow,
        )
        self.stats = TT.init_drift_stats(self.scfg)
        # snapshot of `stats` at the most recent policy fire: each commit
        # REPLACES the stats dict, so holding the reference is a consistent
        # point-in-time view for the (possibly background) control plane
        self.trigger_stats = self.stats
        self.t_cp_s = (
            self.cfg.t_cp_s
            or engine.fcfg.t_cp_s
            or TT.TwoTimescaleConfig().t_cp_seconds
        )
        self.controller = controller if controller is not None else (
            # every fired policy IS a control-plane epoch (t_cp_steps=1) and
            # the Eq. 20 churn gate defers to the drift policy (tau_map=0)
            TT.TwoTimescaleController(
                TT.TwoTimescaleConfig(
                    t_cp_steps=1, tau_map=0.0, t_cp_seconds=self.t_cp_s
                ),
                n_centroids=ccfg.n_classes,
            )
        )
        self.relearn = relearn if relearn is not None else default_relearn
        self.history: List[AdaptationRecord] = []
        self.metrics: Dict[str, float] = {n: 0.0 for n in _METRIC_NAMES}
        self.centroids = jnp.zeros(
            (ccfg.n_classes, ccfg.arch.d_model), jnp.float32
        )
        self._tick = 0
        self._last_fire: Optional[int] = None
        self._epoch = 0  # control-plane epoch counter
        self._lock = threading.Lock()  # guards centroids/controller state
        self._executor = (
            None if self.cfg.sync
            else ThreadPoolExecutor(max_workers=1, thread_name_prefix="chimera-cp")
        )
        self._pending: Optional[Tuple[Future, Dict[str, float], Tuple[str, ...], int]] = None

        # the jitted drift path: fixed (stats_lanes,) shapes end to end, so
        # this traces exactly twice (summarize + commit) for the loop's life
        self._jit_summarize = jax.jit(
            lambda pred, trust, veto, sig, valid: TT.summarize_drift_chunk(
                self.scfg, pred, trust, veto, sig, valid
            )
        )

        def _commit(stats, summary, churn):
            new = TT.commit_drift(self.scfg, stats, summary, churn)
            return new, TT.drift_metrics(self.scfg, new)

        self._jit_commit = jax.jit(_commit)

    def jit_entry_points(self):
        """Named jitted hot-path callables, for the retrace sentry: the
        drift paths plus the inner engine's (namespaced ``engine.*``)."""
        entries = {
            "summarize": self._jit_summarize,
            "commit": self._jit_commit,
        }
        for name, fn in self.engine.jit_entry_points().items():
            entries[f"engine.{name}"] = fn
        return entries

    # ------------------------------------------------------------------
    # fast path
    # ------------------------------------------------------------------
    def ingest(self, flow_ids: np.ndarray, tokens: np.ndarray) -> Dict[str, np.ndarray]:
        """One engine tick plus the drift bookkeeping around it.  Same
        contract as ``FlowEngine.ingest``; a finished background delta is
        installed *before* the batch (at the tick boundary), and a fired
        policy schedules (async) or runs (sync) the control plane after."""
        self._install_if_ready()
        created0 = self.engine.stats.flows_created
        out = self.engine.ingest(flow_ids, tokens)
        self._tick += 1
        P = len(out["trust"])
        if P:
            churn = (self.engine.stats.flows_created - created0) / P
            self._update_stats(out, churn)
        fired = self._policy_check()
        if fired:
            self._last_fire = self._tick
            trigger = dict(self.metrics)
            self.trigger_stats = self.stats  # freeze the firing tick's view
            if self.cfg.sync:
                self._run_epoch(trigger, fired, self._tick)
            else:
                self._epoch += 1
                fut = self._executor.submit(self._compile_epoch, trigger, fired, self._epoch)
                self._pending = (fut, trigger, fired, self._tick)
        return out

    def run(self, scenario, batches: int) -> List[Dict[str, np.ndarray]]:
        """Stream ``batches`` scenario batches through the loop."""
        outs = []
        for _ in range(batches):
            b = scenario.next_batch()
            outs.append(self.ingest(b["flow_ids"], b["tokens"]))
        return outs

    # ------------------------------------------------------------------
    # drift statistics (on-device, fixed shapes)
    # ------------------------------------------------------------------
    def _update_stats(self, out: Dict[str, np.ndarray], churn: float) -> None:
        L = self.cfg.stats_lanes
        W = self.engine.ccfg.sig_words
        P = len(out["trust"])
        total = None
        for c0 in range(0, P, L):
            n = min(L, P - c0)
            pred = np.zeros((L,), np.int32)
            trust = np.zeros((L,), np.float32)
            veto = np.zeros((L,), bool)
            sig = np.zeros((L, W), np.uint32)
            valid = np.zeros((L,), bool)
            pred[:n] = out["pred"][c0 : c0 + n]
            trust[:n] = out["trust"][c0 : c0 + n]
            veto[:n] = out["vetoed"][c0 : c0 + n]
            sig[:n] = out["sig"][c0 : c0 + n]
            valid[:n] = True
            s = self._jit_summarize(
                jnp.asarray(pred), jnp.asarray(trust), jnp.asarray(veto),
                jnp.asarray(sig), jnp.asarray(valid),
            )
            total = s if total is None else TT.merge_drift_summaries(total, s)
        self.stats, m = self._jit_commit(
            self.stats, total, jnp.float32(churn)
        )
        self.metrics = {k: float(v) for k, v in m.items()}
        self._observe_features()

    def _observe_features(self) -> None:
        feats = self._harvest_pooled(self.cfg.observe_cap)
        if feats is not None and len(feats):
            self.controller.observe(feats)

    def _harvest_pooled(self, cap: int) -> Optional[np.ndarray]:
        """Pooled hidden features of up to ``cap`` resident flows (the
        control plane's recluster reservoir) — slot order, so the sample is
        deterministic for a replayed stream."""
        eng = self.engine
        rows: List[np.ndarray] = []
        have = 0
        if hasattr(eng, "tables"):  # sharded: per-shard slot-batched state
            for s, t in enumerate(eng.tables):
                slots = sorted(t.fid_of)[: cap - have]
                if not slots:
                    continue
                idx = jnp.asarray(slots, jnp.int32)
                pos = jnp.maximum(eng.positions[s, idx], 1)[:, None]
                rows.append(np.asarray(
                    eng.hidden_sum[s, idx] / pos, np.float32
                ))
                have += len(slots)
                if have >= cap:
                    break
        else:
            slots = sorted(eng.table.fid_of)[:cap]
            if slots:
                idx = jnp.asarray(slots, jnp.int32)
                pos = jnp.maximum(eng.positions[idx], 1)[:, None]
                rows.append(np.asarray(eng.hidden_sum[idx] / pos, np.float32))
        return np.concatenate(rows, axis=0) if rows else None

    # ------------------------------------------------------------------
    # drift policy
    # ------------------------------------------------------------------
    def _policy_check(self) -> Tuple[str, ...]:
        if self._tick <= self.policy.warmup_ticks:
            return ()
        if (
            self._last_fire is not None
            and self._tick - self._last_fire <= self.policy.cooldown_ticks
        ):
            return ()
        if self._pending is not None:
            return ()  # one control-plane epoch in flight at a time
        return self.policy.fired(self.metrics)

    @property
    def trigger_ticks(self) -> List[int]:
        return [r.tick for r in self.history]

    @property
    def installs(self) -> int:
        return sum(r.installed for r in self.history)

    @property
    def installs_within_budget(self) -> int:
        return sum(r.installed and r.churn_ok for r in self.history)

    # ------------------------------------------------------------------
    # slow path: recluster -> audited delta -> measured atomic install
    # ------------------------------------------------------------------
    def _compile_epoch(self, trigger, fired, epoch):
        """Recluster + delta compilation (thread-safe: touches controller
        and centroids under the lock, never the engine)."""
        with self._lock:
            learned = self.relearn(self, trigger, fired) or {}
            try:
                cent, rec, delta = self.controller.maybe_recluster(
                    step=epoch * self.controller.cfg.t_cp_steps,
                    centroids=self.centroids,
                    occupancy=self.trigger_stats["class_fast"],
                    key=jax.random.PRNGKey(epoch),
                    program=self.engine.program,
                    new_weights=learned.get("new_weights"),
                    new_ruleset=learned.get("ruleset"),
                )
            except BudgetError as e:
                return None, None, f"BudgetError: {e}"
            self.centroids = cent
            if rec is None:
                return None, None, "no-observations"
            if delta is None:
                return rec, None, "recluster-held"
            return rec, delta, None

    def _run_epoch(self, trigger, fired, fire_tick) -> AdaptationRecord:
        self._epoch += 1
        rec, delta, err = self._compile_epoch(trigger, fired, self._epoch)
        return self._install(rec, delta, err, trigger, fired, fire_tick)

    def _install_if_ready(self) -> None:
        if self._pending is None:
            return
        fut, trigger, fired, fire_tick = self._pending
        if not fut.done():
            return
        self._pending = None
        rec, delta, err = fut.result()
        self._install(rec, delta, err, trigger, fired, fire_tick)

    def _install(self, rec, delta, err, trigger, fired, fire_tick) -> AdaptationRecord:
        record = AdaptationRecord(
            tick=fire_tick,
            trigger=trigger,
            fired_on=fired,
            installed=False,
            install_tick=self._tick,
            t_cp_s=self.t_cp_s,
            delta_step=self._epoch,
            recluster=dataclasses.asdict(rec) if rec is not None else None,
        )
        if err is not None or delta is None:
            record.error = err
            self.history.append(record)
            return record
        prev_rules = self.engine.rules
        swap = self.engine.swap_tables(delta=delta)
        record.install_s = swap.install_s
        record.churn_ok = hardware_model.install_time_ok(
            swap.install_s, self.t_cp_s
        )
        record.ledger_diff = self.engine.program.ledger.diff(delta.ledger)
        if not record.churn_ok:
            # Eq. 18 violated: the install did not complete inside the
            # control epoch — put the previous tables back (also measured,
            # also atomic) rather than serving a half-trusted deployment
            self.engine.swap_tables(ruleset=prev_rules)
            record.rolled_back = True
            record.error = (
                f"install {swap.install_s:.3f}s exceeded t_cp "
                f"{self.t_cp_s:.3f}s (Eq. 18); rolled back"
            )
        else:
            record.installed = True
        self.history.append(record)
        return record

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Wait for an in-flight background epoch and install its delta
        (call between scenario phases / before reading final history)."""
        if self._pending is None:
            return
        self._pending[0].result()
        self._install_if_ready()

    def close(self) -> None:
        self.flush()
        if self._executor is not None:
            self._executor.shutdown(wait=True)

    def __enter__(self) -> "AdaptiveLoop":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
