"""Flow-table streaming inference runtime (DESIGN.md §10).

The serving-side realization of Algorithm 1 for the paper's *traffic*
workload: a flow-keyed table maps 5-tuple-style flow IDs to bounded
per-flow Chimera state — the Eq. 11/13 O(L·d + m·d_v) decode state plus the
streaming classifier aggregates (running pooled features, cumulative packed
marker signature, sticky TCAM veto bit).  ``ingest(flow_ids, tokens)``
batches every touched flow through ONE jitted classifier step per arrival
round (same-flow packets are serialized by :func:`arrival_rounds`; distinct
flows vectorize), so millions of interleaved flows stream through a single
compiled program regardless of arrival order.

Trust on the hot path: every packet's cumulative signature is ternary-matched
against the installed :class:`RuleSet`; a hard TCAM hit marks the flow
*vetoed* for its lifetime and cascade fusion (Eq. 15) then pins S = 1
regardless of the neural score.

Two timescales: the data plane only ever *reads* the compiled tables inside
the jitted step; the control plane calls :meth:`FlowEngine.swap_tables`
between ticks to atomically install a new RuleSet / quantized SRAM weight
table.  Installs are shape-checked so the hot path never retraces (Eq. 18).

State is bounded twice over: per-flow by construction (Chimera decode state
never grows with flow length) and table-wide by an explicit byte budget
(:func:`repro.core.hardware_model.check_flow_table_budget`) with LRU and
idle eviction keeping the resident set inside ``capacity``.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hardware_model
from repro.core import symbolic
from repro.core.hardware_model import DEFAULT_DATAPLANE
from repro.data.pipeline import arrival_rounds
from repro.models import model as M
from repro.train import classifier as C


@dataclasses.dataclass(frozen=True)
class FlowEngineConfig:
    capacity: int = 4096  # max resident flows (table entries)
    lanes: int = 256  # jit batch width per arrival round (padded, fixed)
    state_budget_bytes: int = 0  # 0 → DataplaneSpec shared-SRAM default
    idle_timeout: int = 0  # ticks without traffic before eviction (0 = off)
    max_flow_tokens: int = 1024  # KV length for non-Chimera archs only
    t_cp_s: float = 0.0  # control-plane epoch for Eq. 18 checks (0 = off)
    backend: Optional[str] = None  # kernel backend ("xla" | dispatch name)
    horizon: int = 1024  # Eq. 39 flow-length horizon (int-emulation lowering)
    fused: bool = False  # single-launch fused ingest (flow_ingest family)
    min_chunk_lanes: int = 8  # smallest padded width for tail arrival rounds
    ring_slots: int = 4  # host staging-ring depth (AsyncIngestPipeline)


@dataclasses.dataclass
class FlowStats:
    packets: int = 0
    tokens: int = 0
    ticks: int = 0
    rounds: int = 0
    flows_created: int = 0
    flows_evicted_lru: int = 0
    flows_evicted_idle: int = 0

    @property
    def flows_evicted(self) -> int:
        return self.flows_evicted_lru + self.flows_evicted_idle

    @property
    def eviction_rate(self) -> float:
        """Evictions per engine tick — the flow-churn pressure metric."""
        return self.flows_evicted / max(self.ticks, 1)


@dataclasses.dataclass(frozen=True)
class SwapRecord:
    tick: int
    install_s: float  # measured wall-clock install (device-ready, Eq. 18)
    churn_ok: bool  # Eq. 18: install completed within the control epoch
    t_cp_s: float = 0.0  # the control-plane epoch the install was held to
    source: str = "manual"  # "manual" | "delta" (audited ProgramDelta)


def make_flow_step(
    ccfg: C.ClassifierConfig, n_slots: int, int_plan=None, *, score_fn=None
):
    """Build the jitted flow-table update step over ``n_slots`` table rows.

    One arrival round of lanes: gather the touched rows (lazily zeroing
    freshly-allocated slots), scan the packet tokens through
    :func:`repro.models.model.decode_hidden_step`, accumulate the packed
    marker signature, score via :func:`repro.train.classifier
    .streaming_scores`, scatter the rows back.  Module-level so
    :class:`FlowEngine` and :class:`repro.serve.sharded_flow_engine
    .ShardedFlowEngine` run the *same* traced function — one shard of a
    sharded table is exactly a single-device table, which is what makes
    sharded replay bit-identical to single-device replay.

    With an :class:`~repro.compile.int_lowering.IntScorePlan`, the score
    path runs the integer-lowered program instead (the ``int-emulation``
    backend): features are quantized at the Map boundary, ``hidden_sum`` is
    the int32 fixed-point accumulator, and the ``rules`` argument carries
    ``(rules, int_tables)`` so table swaps reuse the traced step.  The
    backbone scan is unchanged (float, bit-identical to the xla path).

    ``score_fn`` (float path only) swaps the streaming-score stage for a
    kernel implementation with the same canonical signature
    ``(params, rules, pooled, sig, sticky) -> (outputs, new_sticky)`` — the
    hook the ``flow_ingest`` Pallas backends use; ``None`` keeps the
    :func:`repro.train.classifier.streaming_scores` oracle.
    """
    arch = ccfg.arch
    if int_plan is not None:
        from repro.compile.int_lowering import dequantize_scores, quantize_features
        from repro.kernels.dispatch import resolve

        int_score = resolve("flow_score", "int-emulation")

    def slotted(c) -> bool:
        return c.ndim >= 2 and c.shape[1] == n_slots

    def step(params, rules, caches, positions, sig, hidden_sum, vetoed,
             idx, tokens, fresh):
        if int_plan is not None:
            rules, int_tables = rules

        # gather the touched rows; zero lanes holding newly-alloc'd flows
        # (slot reuse after eviction must look like a fresh table entry)
        def take(c):
            if not slotted(c):
                return c
            f = fresh.reshape((1, -1) + (1,) * (c.ndim - 2))
            return jnp.where(f, jnp.zeros_like(c[:, idx]), c[:, idx])

        cs = jax.tree_util.tree_map(take, caches)
        pos = jnp.where(fresh, 0, positions[idx])
        sg = jnp.where(fresh[:, None], jnp.uint32(0), sig[idx])
        hs_rows = hidden_sum[idx]
        hs = jnp.where(fresh[:, None], jnp.zeros_like(hs_rows), hs_rows)
        vt = jnp.where(fresh, False, vetoed[idx])

        def body(carry, tok_t):
            cs, pos, hs = carry
            h, cs = M.decode_hidden_step(arch, params["backbone"], tok_t, pos, cs)
            if int_plan is not None:  # the one float->int crossing (Map stage)
                h = quantize_features(int_plan, h)
            else:
                h = h.astype(jnp.float32)
            return (cs, pos + 1, hs + h), None

        (cs, pos, hs), _ = jax.lax.scan(body, (cs, pos, hs), tokens.T)
        sg = sg | C.packet_signature(ccfg, tokens)
        if int_plan is not None:
            out, vt = int_score(int_plan, int_tables, rules, hs, pos, sg, vt)
            out = dequantize_scores(int_plan, out)  # engine float contract
        else:
            pooled = hs / jnp.maximum(pos, 1)[:, None].astype(jnp.float32)
            if score_fn is not None:
                out, vt = score_fn(params, rules, pooled, sg, vt)
            else:
                out, vt = C.streaming_scores(ccfg, params, rules, pooled, sg, vt)
        out["sig"] = sg  # cumulative signature after this packet (drift stats)

        def put(c, u):
            return c.at[:, idx].set(u) if slotted(c) else c

        caches = jax.tree_util.tree_map(put, caches, cs)
        positions = positions.at[idx].set(pos)
        sig = sig.at[idx].set(sg)
        hidden_sum = hidden_sum.at[idx].set(hs)
        vetoed = vetoed.at[idx].set(vt)
        return caches, positions, sig, hidden_sum, vetoed, out

    return step


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


# chunk-axis bucket floor for fused launches: the chunk stack is padded to
# max(8, next_pow2(C)).  Padded chunks cost only host-buffer transfer (the
# traced n_chunks trip count skips them on device), while the floor pins the
# launch shape for every group of ≤ 8 chunks — so steady-state serving sees
# ONE trace per width instead of one per (width, chunk-count) pair.
_CHUNK_FLOOR = 8


def pack_width_groups(
    slots: np.ndarray, lanes: int, min_lanes: int = 8
) -> List[Tuple[int, List[np.ndarray]]]:
    """Pre-pack arrival rounds into width-bucketed chunk groups.

    The per-round hot path pads *every* round to the full ``lanes`` width,
    so a heavy-tail flow that forces 8 arrival rounds costs 8 full-width
    launches even when the late rounds hold a handful of packets.  Here
    each round is split into chunks of at most ``lanes`` packets, each
    chunk is assigned the smallest power-of-two width that holds it
    (clamped to ``[min_lanes, lanes]``), and *consecutive* chunks sharing a
    width are grouped so one fused launch scans them all.  Order across
    groups preserves round order — round r+1 of a flow always executes
    after round r (consecutive rounds can never merge: every flow in round
    r+1 also appears in round r by construction).

    Returns ``[(width, [packet-index arrays])]``.
    """
    groups: List[Tuple[int, List[np.ndarray]]] = []
    for round_lanes in arrival_rounds(list(slots)):
        for c0 in range(0, len(round_lanes), lanes):
            ch = np.asarray(round_lanes[c0 : c0 + lanes], np.intp)
            w = min(lanes, _next_pow2(max(len(ch), min_lanes)))
            if groups and groups[-1][0] == w:
                groups[-1][1].append(ch)
            else:
                groups.append((w, [ch]))
    return groups


def make_fused_ingest(
    ccfg: C.ClassifierConfig, n_slots: int, int_plan=None, *, score_fn=None
):
    """Build the fused whole-batch ingest step (``flow_ingest`` family).

    One launch consumes a stack of pre-packed arrival-round chunks: the
    flow table stays resident on-device while an on-device loop runs the
    *identical* :func:`make_flow_step` body — gather by slot, token decode
    scan, streaming scores + TCAM veto, scatter-update — once per chunk.
    Because the loop body is the same traced function the per-round engine
    jits, the fused path is bit-exact to the per-round path by
    construction (the ``reference`` backend's conformance contract).

    Signature of the returned callable::

        fused(params, rules, caches, positions, sig, hidden_sum, vetoed,
              idx (C, w) int32, tokens (C, w, pkt_len) int32,
              fresh (C, w) bool, n_chunks () int32)
          -> (caches, positions, sig, hidden_sum, vetoed, outs)

    ``C`` may exceed ``n_chunks`` (the host pads the chunk axis to a
    power-of-two bucket so varying round counts never retrace); padding
    chunks are *skipped*, not masked — the loop trip count is the traced
    ``n_chunks`` scalar, so they cost nothing.  ``outs`` stacks the
    per-chunk score outputs on a leading ``C`` axis (rows ≥ ``n_chunks``
    stay zero).
    """
    step = make_flow_step(ccfg, n_slots, int_plan=int_plan, score_fn=score_fn)

    def fused(params, rules, caches, positions, sig, hidden_sum, vetoed,
              idx, tokens, fresh, n_chunks):
        C = idx.shape[0]
        out_ab = jax.eval_shape(
            step, params, rules, caches, positions, sig, hidden_sum, vetoed,
            idx[0], tokens[0], fresh[0],
        )[5]
        outs0 = jax.tree_util.tree_map(
            lambda a: jnp.zeros((C,) + a.shape, a.dtype), out_ab
        )

        def body(j, carry):
            caches, positions, sig, hidden_sum, vetoed, outs = carry

            def at(x):
                return jax.lax.dynamic_index_in_dim(x, j, 0, keepdims=False)

            caches, positions, sig, hidden_sum, vetoed, out = step(
                params, rules, caches, positions, sig, hidden_sum, vetoed,
                at(idx), at(tokens), at(fresh),
            )
            outs = jax.tree_util.tree_map(
                lambda buf, o: jax.lax.dynamic_update_index_in_dim(buf, o, j, 0),
                outs, out,
            )
            return caches, positions, sig, hidden_sum, vetoed, outs

        return jax.lax.fori_loop(
            0, n_chunks, body,
            (caches, positions, sig, hidden_sum, vetoed, outs0),
        )

    return fused


class _PendingIngest:
    """Handle for a dispatched-but-unharvested fused ingest batch.

    :meth:`FlowEngine._dispatch_fused` returns one of these *before*
    blocking on device results, so the async pipeline can pack and dispatch
    the next batch while the device chews on this one.  ``finalize()``
    blocks (the first host read of the output arrays) and unpacks the
    per-chunk score stacks into the per-packet dict ``ingest`` returns.
    """

    def __init__(self, engine, flow_ids, n_packets: int, launches):
        self.engine = engine
        self.flow_ids = flow_ids
        self.n_packets = n_packets
        self.launches = launches  # [(outs pytree, [chunk packet-index arrays])]
        self._result: Optional[Dict[str, np.ndarray]] = None

    def finalize(self) -> Dict[str, np.ndarray]:
        if self._result is not None:
            return self._result
        P = self.n_packets
        out = {
            "flow_ids": self.flow_ids,
            "trust": np.empty((P,), np.float32),
            "vetoed": np.empty((P,), bool),
            "pred": np.empty((P,), np.int32),
            "s_nn": np.empty((P,), np.float32),
            "s_sym": np.empty((P,), np.float32),
            "sig": np.zeros((P, self.engine.ccfg.sig_words), np.uint32),
        }
        for outs, chunks in self.launches:
            trust = np.asarray(outs["trust"], np.float32)
            hard = np.asarray(outs["hard_hit"])
            logits = np.asarray(outs["class_logits"])
            s_nn = np.asarray(outs["s_nn"], np.float32)
            s_sym = np.asarray(outs["s_sym"], np.float32)
            sig = np.asarray(outs["sig"])
            for j, ch in enumerate(chunks):
                n = len(ch)
                out["trust"][ch] = trust[j, :n]
                out["vetoed"][ch] = hard[j, :n]
                out["pred"][ch] = np.argmax(logits[j, :n], -1).astype(np.int32)
                out["s_nn"][ch] = s_nn[j, :n]
                out["s_sym"][ch] = s_sym[j, :n]
                out["sig"][ch] = sig[j, :n]
        self._result = out
        return out


class FlowTableDirectory:
    """Host-side slot allocator for one flow table (or one shard of one):
    fid → slot map, free list, LRU timestamps.  Owns no device state — the
    caller pairs it with the slot-batched arrays the jitted step updates.
    Extracted from :class:`FlowEngine` so :class:`~repro.serve
    .sharded_flow_engine.ShardedFlowEngine` runs one directory per shard
    with identical allocation/eviction semantics."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.slot_of: Dict[int, int] = {}
        self.fid_of: Dict[int, int] = {}
        self.free: List[int] = list(range(capacity - 1, -1, -1))
        self.last_seen = np.full((capacity,), np.iinfo(np.int64).max, np.int64)

    @property
    def resident(self) -> int:
        return len(self.slot_of)

    def touch(self, fid: int, tick: int) -> bool:
        """Refresh a resident flow's LRU stamp; False if not resident."""
        slot = self.slot_of.get(fid)
        if slot is None:
            return False
        self.last_seen[slot] = tick
        return True

    def slot_for(self, fid: int, tick: int) -> Tuple[int, bool, bool]:
        """Resolve ``fid`` to a table slot, allocating (free list, else LRU
        victim) when absent.  Returns ``(slot, fresh, lru_evicted)``."""
        slot = self.slot_of.get(fid)
        if slot is not None:
            self.last_seen[slot] = tick
            return slot, False, False
        evicted = False
        if self.free:
            slot = self.free.pop()
        else:
            slot = int(np.argmin(self.last_seen))  # LRU victim
            del self.slot_of[self.fid_of[slot]]
            evicted = True
        self.slot_of[fid] = slot
        self.fid_of[slot] = fid
        self.last_seen[slot] = tick
        return slot, True, evicted

    def evict(self, fid: int) -> bool:
        slot = self.slot_of.pop(fid, None)
        if slot is None:
            return False
        del self.fid_of[slot]
        self.last_seen[slot] = np.iinfo(np.int64).max
        self.free.append(slot)
        return True

    def idle_victims(self, horizon: int) -> List[int]:
        """Flows whose last packet predates ``horizon`` (exclusive)."""
        return [f for f, s in self.slot_of.items() if self.last_seen[s] < horizon]

    def reset(self) -> None:
        self.slot_of.clear()
        self.fid_of.clear()
        self.free = list(range(self.capacity - 1, -1, -1))
        self.last_seen[:] = np.iinfo(np.int64).max


def resolve_swap(
    old: symbolic.RuleSet,
    ruleset: Optional[symbolic.RuleSet],
    weights,
    weight_spec,
    delta,
) -> Tuple[symbolic.RuleSet, str]:
    """Resolve a ``swap_tables`` request into the RuleSet to install.

    Accepts either raw tables (``ruleset`` and/or ``weights`` — float or a
    quantized Eq. 19 SRAM table plus its ``FixedPointSpec``) or an audited
    :class:`repro.compile.ProgramDelta`, and shape/dtype-checks the result
    against the installed tables so the jitted ingest step is reused
    verbatim — a swap never recompiles the hot path.  Shared by
    :class:`FlowEngine` and the sharded engine (identical install
    semantics; only the placement differs).  Returns ``(new, source)``.
    """
    source = "manual"
    if delta is not None:
        if ruleset is not None or weights is not None:
            raise ValueError("pass either a ProgramDelta or raw tables, not both")
        ruleset = delta.ruleset
        weights, weight_spec = delta.weight_table, delta.weight_spec
        source = "delta"
    new = ruleset if ruleset is not None else old
    if weights is not None:
        w = (
            symbolic.decompile_table(weights, weight_spec)
            if weight_spec is not None
            else jnp.asarray(weights, jnp.float32)
        )
        new = symbolic.RuleSet(
            values=new.values, masks=new.masks,
            weights=w.astype(jnp.float32), hard=new.hard,
        )
    for name in ("values", "masks", "weights", "hard"):
        a, b = getattr(old, name), getattr(new, name)
        if a.shape != b.shape or a.dtype != b.dtype:
            raise ValueError(
                f"swap_tables: {name} {b.shape}/{b.dtype} does not match "
                f"installed {a.shape}/{a.dtype}; shape-changing installs "
                f"would retrace the hot path (rebuild the engine instead)"
            )
    return new, source


def _engine_kwargs_from_program(program, backend: Optional[str] = None) -> Dict:
    """The constructor inputs every ``from_program`` deploy path shares
    (:class:`FlowEngine`, :class:`~repro.serve.sharded_flow_engine
    .ShardedFlowEngine`, :class:`~repro.serve.engine.ServeEngine`): the
    program's compiled classifier config, parameters and packed rules, plus
    the kernel backend — the program's pass-selected backend unless the
    deployment site overrides it."""
    return {
        "ccfg": program.ccfg,
        "params": program.params,
        "rules": program.rules,
        "backend": backend if backend is not None else program.backend,
    }


class FlowEngine:
    """Streaming per-flow classification over a bounded flow table."""

    def __init__(
        self,
        ccfg: C.ClassifierConfig,
        params,
        rules: symbolic.RuleSet,
        fcfg: FlowEngineConfig = FlowEngineConfig(),
    ):
        from repro.kernels.dispatch import apply_kernel_backend

        arch, self.backend = apply_kernel_backend(ccfg.arch, fcfg.backend)
        self.ccfg = dataclasses.replace(ccfg, arch=arch)
        self.fcfg = fcfg
        self.params = params
        self.rules = rules
        self.stats = FlowStats()
        self.swap_history: List[SwapRecord] = []
        self.program = None  # set by from_program

        # int-emulation: lower the score path to fixed point.  The plan is a
        # pure function of (ccfg, params, rules, horizon), so program
        # save/load and swap installs need no extra serialized state.  A
        # >32-bit lowering raises BudgetError here — int32 emulation of a
        # wider program would silently wrap, so it is never deployable.
        self._int_plan = None
        self._int_tables = None
        self._int_entries: List = []
        if self.backend == "int-emulation":
            from repro.compile.int_lowering import lower_scores
            from repro.compile.ledger import ResourceLedger

            self._int_plan, self._int_tables, self._int_entries = lower_scores(
                self.ccfg, params, rules, horizon=fcfg.horizon
            )
            deploy_ledger = ResourceLedger()
            deploy_ledger.extend(self._int_entries)
            deploy_ledger.raise_if_over()

        # slot-batched state: capacity real slots + one scratch slot that
        # absorbs padding lanes (index == capacity)
        self._n_slots = fcfg.capacity + 1
        self.caches = M.init_caches(
            arch, self._n_slots, fcfg.max_flow_tokens, dtype=jnp.float32
        )
        W, d = ccfg.sig_words, arch.d_model
        self.positions = jnp.zeros((self._n_slots,), jnp.int32)
        self.sig = jnp.zeros((self._n_slots, W), jnp.uint32)
        hs_dtype = jnp.int32 if self._int_plan is not None else jnp.float32
        self.hidden_sum = jnp.zeros((self._n_slots, d), hs_dtype)
        self.vetoed = jnp.zeros((self._n_slots,), bool)

        # host-side table bookkeeping
        self.table = FlowTableDirectory(fcfg.capacity)
        self._tick = 0

        # Eq. 11 budget check, enforced at construction so an over-provisioned
        # table cannot even be built; the check covers everything actually
        # allocated (capacity entries + the scratch lane)
        budget = fcfg.state_budget_bytes or DEFAULT_DATAPLANE.sram_total_bits // 8
        self.state_budget_bytes = budget
        hardware_model.check_flow_table_budget(
            self._n_slots, self.per_flow_state_bytes(), budget
        )

        self._jit_step = jax.jit(
            self._make_step(), donate_argnums=(2, 3, 4, 5, 6)
        )

        # fused single-launch ingest (flow_ingest kernel family): one jitted
        # callable shared by every (width, chunk-bucket) shape — the pow2
        # bucketing in _dispatch_fused bounds its trace count.  The kernel
        # backends only differ in the score stage; xla / int-emulation fall
        # back to the reference builder (same fused structure, oracle
        # scores), so --fused composes with every backend.
        self._jit_fused = None
        self._staging: Dict[Tuple[int, int, int, int], Dict[str, np.ndarray]] = {}
        if fcfg.fused:
            from repro.kernels import autotune
            from repro.kernels.dispatch import resolve

            fam_backend = (
                self.backend
                if self.backend in ("pallas-tpu", "pallas-interpret")
                else "reference"
            )
            tiles = None
            if fam_backend != "reference":
                tiles = autotune.get_tiles(
                    "flow_ingest", self.flow_ingest_dims(), fam_backend
                )
            self._jit_fused = jax.jit(
                resolve("flow_ingest", fam_backend)(
                    self.ccfg, self._n_slots, int_plan=self._int_plan,
                    tiles=tiles,
                ),
                donate_argnums=(2, 3, 4, 5, 6),
            )

    def jit_entry_points(self) -> Dict[str, Any]:
        """Named jitted hot-path callables, for the retrace sentry
        (:class:`repro.analysis.retrace_sentry.RetraceSentry`)."""
        entries: Dict[str, Any] = {"step": self._jit_step}
        if self._jit_fused is not None:
            entries["fused"] = self._jit_fused
        return entries

    def flow_ingest_dims(self) -> Dict[str, int]:
        """Problem dims the autotuner keys the flow_ingest sweep on."""
        return {
            "lanes": self.fcfg.lanes,
            "d": self.ccfg.arch.d_model,
            "w_words": self.ccfg.sig_words,
            "rules": int(self.rules.weights.shape[0]),
            "n_classes": self.ccfg.n_classes,
        }

    def warm_fused(self, pkt_len: int, max_chunks: int = _CHUNK_FLOOR) -> int:
        """Pre-trace every fused launch shape traffic can produce.

        One dummy scratch-only launch per pow2 width in
        [min_chunk_lanes, lanes] at the chunk-bucket floor — after this,
        steady-state ingest never retraces (until a batch exceeds
        ``max_chunks`` same-width chunks, which escalates the bucket).
        Scratch-row launches don't perturb real flow state.  Returns the
        number of shapes traced.  Optional: serving works without it, at
        the cost of first-contact traces mid-stream.
        """
        if self._jit_fused is None:
            return 0
        scratch = self.fcfg.capacity
        c_pad = max(_CHUNK_FLOOR, _next_pow2(max_chunks))
        # pack_width_groups buckets a chunk to _next_pow2(max(len, min_lanes))
        # clamped to lanes, so the widths traffic can produce are the pow2s
        # from _next_pow2(min_chunk_lanes) up to lanes, plus lanes itself when
        # it is not a power of two.  Start at the rounded-up pow2 so a
        # non-pow2 min_chunk_lanes (e.g. 12) warms the real buckets (16,
        # 32, ...) instead of widths that never occur.
        widths = []
        w = min(
            self.fcfg.lanes, _next_pow2(max(self.fcfg.min_chunk_lanes, 1))
        )
        while w < self.fcfg.lanes:
            widths.append(w)
            w *= 2
        widths.append(self.fcfg.lanes)
        for w in widths:
            idx = jnp.full((c_pad, w), scratch, jnp.int32)
            tok = jnp.zeros((c_pad, w, pkt_len), jnp.int32)
            fr = jnp.zeros((c_pad, w), bool)
            (self.caches, self.positions, self.sig, self.hidden_sum,
             self.vetoed, _) = self._jit_fused(
                self.params, self._step_rules(), self.caches, self.positions,
                self.sig, self.hidden_sum, self.vetoed,
                idx, tok, fr, jnp.int32(0),
            )
        return len(widths)

    # ------------------------------------------------------------------
    # compiled-program deployment (deprecated shim — DESIGN.md §17.4)
    # ------------------------------------------------------------------
    @classmethod
    def from_program(
        cls, program, fcfg: FlowEngineConfig = FlowEngineConfig()
    ) -> "FlowEngine":
        """Deprecated: deploy through the one front door instead —
        ``program.deploy(DeploySpec(engine="flow", flow=fcfg))``."""
        warnings.warn(
            "FlowEngine.from_program is deprecated; use "
            "DataplaneProgram.deploy(DeploySpec(engine='flow', flow=fcfg)) "
            "— the shim will be removed one release cycle after DeploySpec "
            "landed (DESIGN.md §17.4)",
            DeprecationWarning, stacklevel=2,
        )
        from repro.serve.deploy import build_flow_engine

        return build_flow_engine(program, fcfg)

    # ------------------------------------------------------------------
    # state accounting
    # ------------------------------------------------------------------
    def per_flow_state_bytes(self) -> int:
        """Actual bytes of one flow-table entry: Chimera decode state
        (Eq. 11/13: S, Z, ring buffers, fill count) + classifier aggregates
        (signature words, pooled-feature accumulator, counters, veto bit)."""
        cache_bytes = sum(
            leaf.nbytes // self._n_slots
            for leaf in jax.tree_util.tree_leaves(self.caches)
        )
        aux = (
            self.sig.nbytes
            + self.hidden_sum.nbytes
            + self.positions.nbytes
            + self.vetoed.nbytes
        ) // self._n_slots
        return cache_bytes + aux + 8  # + host LRU timestamp

    def resident_state_bytes(self) -> int:
        """Total allocated flow-table bytes (capacity + the scratch lane) —
        constant under churn because nothing is allocated per-packet."""
        return hardware_model.flow_table_bytes(
            self._n_slots, self.per_flow_state_bytes()
        )

    @property
    def resident_flows(self) -> int:
        return self.table.resident

    def flow_ids(self) -> List[int]:
        return list(self.table.slot_of)

    # ------------------------------------------------------------------
    # jitted hot path
    # ------------------------------------------------------------------
    def _make_step(self):
        return make_flow_step(self.ccfg, self._n_slots, int_plan=self._int_plan)

    def _step_rules(self):
        """The ``rules`` argument of the jitted step: the packed RuleSet,
        paired with the lowered int tables under int-emulation."""
        if self._int_plan is not None:
            return (self.rules, self._int_tables)
        return self.rules

    # ------------------------------------------------------------------
    # flow-table bookkeeping (host side)
    # ------------------------------------------------------------------
    def _slot_for(self, fid: int) -> Tuple[int, bool]:
        slot, fresh, evicted = self.table.slot_for(fid, self._tick)
        if evicted:
            self.stats.flows_evicted_lru += 1
        if fresh:
            self.stats.flows_created += 1
        return slot, fresh

    def reset(self) -> None:
        """Clear the flow table without touching the jitted step.

        Drops every resident flow and zeroes the stats; device state is NOT
        rewritten — reused slots are lazily zeroed by the per-lane ``fresh``
        flag, so a reset engine keeps its compiled hot path (benchmarks
        sweep scenarios on one engine instead of re-jitting per scenario)."""
        self.table.reset()
        self._tick = 0
        self.stats = FlowStats()

    def evict(self, fid: int) -> bool:
        """Drop a flow's table entry (state is lazily zeroed on slot reuse)."""
        return self.table.evict(fid)

    def evict_idle(self) -> int:
        """Evict flows idle for more than ``idle_timeout`` ticks."""
        if not self.fcfg.idle_timeout:
            return 0
        stale = self.table.idle_victims(self._tick - self.fcfg.idle_timeout)
        for fid in stale:
            self.table.evict(fid)
            self.stats.flows_evicted_idle += 1
        return len(stale)

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def ingest(self, flow_ids: np.ndarray, tokens: np.ndarray) -> Dict[str, np.ndarray]:
        """Stream one batch of packet arrivals through the flow table.

        ``flow_ids`` (P,) int — flow keys in arrival order (repeats allowed:
        same-flow packets are processed sequentially, distinct flows in
        parallel); ``tokens`` (P, pkt_len) int32.  Returns per-packet outputs
        aligned with the input order: ``trust``, ``vetoed``, ``pred``,
        ``s_nn``, ``s_sym`` reflecting each flow's state *after* its packet.

        With ``fcfg.fused`` the batch goes through the single-launch
        ``flow_ingest`` path (:meth:`_dispatch_fused`) instead of one jitted
        launch per arrival round; results are bit-identical by construction.
        """
        flow_ids = np.asarray(flow_ids)
        tokens = np.asarray(tokens, np.int32)
        P, pkt_len = tokens.shape
        assert flow_ids.shape == (P,), (flow_ids.shape, P)
        slots, fresh = self._resolve_slots(flow_ids)
        if self._jit_fused is not None:
            return self._dispatch_fused(flow_ids, tokens, slots, fresh).finalize()
        return self._ingest_rounds(flow_ids, tokens, slots, fresh)

    def _resolve_slots(self, flow_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Host bookkeeping for one batch: tick, LRU touch, idle sweep, slot
        assignment.  Shared verbatim by the per-round and fused paths so both
        observe the identical eviction sequence."""
        self._tick += 1
        self.stats.ticks += 1

        # touch every already-resident flow in this batch BEFORE the idle
        # sweep and any allocation: eviction victims (idle or LRU) must come
        # from flows with no packets pending here, or a resident (possibly
        # vetoed) flow could lose its state on the very tick it transmits.
        # Only when the batch itself holds more distinct flows than the
        # table has entries is evicting an in-batch flow unavoidable (state
        # loss on eviction is inherent to a bounded table).
        for fid in set(flow_ids.tolist()):
            self.table.touch(fid, self._tick)
        self.evict_idle()

        P = len(flow_ids)
        slots = np.empty((P,), np.int32)
        fresh = np.zeros((P,), bool)
        for i, fid in enumerate(flow_ids.tolist()):
            slots[i], fresh[i] = self._slot_for(fid)
        return slots, fresh

    def _ingest_rounds(
        self, flow_ids: np.ndarray, tokens: np.ndarray,
        slots: np.ndarray, fresh: np.ndarray,
    ) -> Dict[str, np.ndarray]:
        """Legacy per-round hot path: one jitted launch per arrival round,
        every round padded to the full ``lanes`` width."""
        P, pkt_len = tokens.shape
        out_trust = np.empty((P,), np.float32)
        out_veto = np.empty((P,), bool)
        out_pred = np.empty((P,), np.int32)
        out_s_nn = np.empty((P,), np.float32)
        out_s_sym = np.empty((P,), np.float32)
        out_sig = np.zeros((P, self.ccfg.sig_words), np.uint32)

        lanes = self.fcfg.lanes
        scratch = self.fcfg.capacity
        for round_lanes in arrival_rounds(slots.tolist()):
            for c0 in range(0, len(round_lanes), lanes):
                chunk = round_lanes[c0 : c0 + lanes]
                idx = np.full((lanes,), scratch, np.int32)
                tok = np.zeros((lanes, pkt_len), np.int32)
                fr = np.zeros((lanes,), bool)
                n = len(chunk)
                idx[:n] = slots[chunk]
                tok[:n] = tokens[chunk]
                fr[:n] = fresh[chunk]
                (self.caches, self.positions, self.sig, self.hidden_sum,
                 self.vetoed, out) = self._jit_step(
                    self.params, self._step_rules(), self.caches, self.positions,
                    self.sig, self.hidden_sum, self.vetoed,
                    jnp.asarray(idx), jnp.asarray(tok), jnp.asarray(fr),
                )
                self.stats.rounds += 1
                lanes_idx = np.asarray(chunk, np.intp)
                out_trust[lanes_idx] = np.asarray(out["trust"], np.float32)[:n]
                out_veto[lanes_idx] = np.asarray(out["hard_hit"])[:n]
                out_pred[lanes_idx] = np.asarray(
                    jnp.argmax(out["class_logits"], -1), np.int32
                )[:n]
                out_s_nn[lanes_idx] = np.asarray(out["s_nn"], np.float32)[:n]
                out_s_sym[lanes_idx] = np.asarray(out["s_sym"], np.float32)[:n]
                out_sig[lanes_idx] = np.asarray(out["sig"])[:n]
        self.stats.packets += P
        self.stats.tokens += P * pkt_len
        return {
            "flow_ids": flow_ids,
            "trust": out_trust,
            "vetoed": out_veto,
            "pred": out_pred,
            "s_nn": out_s_nn,
            "s_sym": out_s_sym,
            "sig": out_sig,
        }

    def _dispatch_fused(
        self, flow_ids: np.ndarray, tokens: np.ndarray,
        slots: np.ndarray, fresh: np.ndarray,
        staging: Optional[Dict] = None,
    ) -> _PendingIngest:
        """Pack this batch's arrival rounds into width-bucketed chunk stacks
        and launch the fused kernel once per width group — then return
        WITHOUT blocking on device results.

        Width bucketing is the dispatch-cost fix: the per-round path pads
        every round to ``lanes``, so the long tail of small rounds (a flow's
        2nd..Nth packet in a batch) pays full-width compute.  Here a round's
        chunks get the smallest pow2 width ≥ its occupancy (floored at
        ``min_chunk_lanes``) and consecutive same-width chunks ride one
        launch.  The chunk axis is also pow2-padded (``fori_loop`` skips the
        padding — its trip count is the traced ``n_chunks``), so the jit
        trace count is bounded by O(log lanes · log chunks) shapes, not by
        traffic shape.

        ``staging`` lets :class:`~repro.serve.ingest_pipeline.AsyncIngestPipeline`
        substitute a ring slot's private buffer pool so host packing of
        batch k+1 never races the in-flight transfer of batch k.
        """
        P, pkt_len = tokens.shape
        lanes, scratch = self.fcfg.lanes, self.fcfg.capacity
        pool = self._staging if staging is None else staging
        launches = []
        # A buffer shape can recur non-consecutively within one batch: every
        # arrival round larger than ``lanes`` emits a full-width group then a
        # smaller tail, so the width sequence looks like [256, 64, 256, 64].
        # Reusing one buffer for both same-shape groups would overwrite data
        # an earlier launch's asynchronous host-to-device transfer may still
        # be reading, so the pool key carries a per-dispatch occurrence index
        # — each use gets its own buffer.  Across dispatches the same
        # (shape, occurrence) sequence maps back to the same buffers, and
        # finalize() (which materializes the launch outputs, hence runs after
        # the input transfers) has completed before a ring slot's pool is
        # reused, so cross-batch reuse stays race-free.
        uses: Dict[Tuple[int, int, int], int] = {}
        for w, chunks in pack_width_groups(
            slots, lanes, self.fcfg.min_chunk_lanes
        ):
            c_pad = max(_CHUNK_FLOOR, _next_pow2(len(chunks)))
            shape = (w, c_pad, pkt_len)
            occ = uses.get(shape, 0)
            uses[shape] = occ + 1
            key = (w, c_pad, pkt_len, occ)
            buf = pool.get(key)
            if buf is None:
                buf = pool[key] = {
                    "idx": np.empty((c_pad, w), np.int32),
                    "tok": np.empty((c_pad, w, pkt_len), np.int32),
                    "fr": np.empty((c_pad, w), bool),
                }
            idx, tok, fr = buf["idx"], buf["tok"], buf["fr"]
            idx.fill(scratch)
            tok.fill(0)
            fr.fill(False)
            for j, ch in enumerate(chunks):
                n = len(ch)
                idx[j, :n] = slots[ch]
                tok[j, :n] = tokens[ch]
                fr[j, :n] = fresh[ch]
            (self.caches, self.positions, self.sig, self.hidden_sum,
             self.vetoed, outs) = self._jit_fused(
                self.params, self._step_rules(), self.caches, self.positions,
                self.sig, self.hidden_sum, self.vetoed,
                jnp.asarray(idx), jnp.asarray(tok), jnp.asarray(fr),
                jnp.int32(len(chunks)),
            )
            self.stats.rounds += len(chunks)
            launches.append((outs, chunks))
        self.stats.packets += P
        self.stats.tokens += P * pkt_len
        return _PendingIngest(self, flow_ids, P, launches)

    # ------------------------------------------------------------------
    # per-flow snapshot
    # ------------------------------------------------------------------
    def flow_scores(self, fid: int) -> Dict[str, float]:
        """Current scores for a resident flow (control-plane read path)."""
        slot = self.table.slot_of[fid]
        if self._int_plan is not None:
            from repro.compile.int_lowering import dequantize_scores
            from repro.kernels.dispatch import resolve

            out, _ = resolve("flow_score", "int-emulation")(
                self._int_plan, self._int_tables, self.rules,
                self.hidden_sum[slot][None], self.positions[slot][None],
                self.sig[slot][None], self.vetoed[slot][None],
            )
            out = dequantize_scores(self._int_plan, out)
        else:
            pooled = self.hidden_sum[slot] / jnp.maximum(self.positions[slot], 1)
            out, _ = C.streaming_scores(
                self.ccfg, self.params, self.rules,
                pooled[None], self.sig[slot][None], self.vetoed[slot][None],
            )
        return {
            "trust": float(out["trust"][0]),
            "vetoed": bool(out["hard_hit"][0]),
            "pred": int(jnp.argmax(out["class_logits"][0])),
            "s_nn": float(out["s_nn"][0]),
            "s_sym": float(out["s_sym"][0]),
            "tokens": int(self.positions[slot]),
        }

    # ------------------------------------------------------------------
    # two-timescale control-plane hook
    # ------------------------------------------------------------------
    def swap_tables(
        self,
        ruleset: Optional[symbolic.RuleSet] = None,
        weights: Optional[jax.Array] = None,
        weight_spec=None,
        delta=None,
    ) -> SwapRecord:
        """Atomically install new compiled tables between ticks (§3.6).

        ``ruleset`` replaces the whole TCAM/SRAM rule table; ``weights``
        replaces only the soft-rule weight column — pass a float array, or a
        quantized SRAM table plus its ``FixedPointSpec`` as ``weight_spec``
        (decompiled on install, Eq. 19's table encoding).  ``delta`` installs
        an audited :class:`repro.compile.ProgramDelta` (the two-timescale
        slow path: controller → compile passes → here).  Shapes and dtypes
        must match the installed tables so the jitted ingest step is reused
        verbatim — a swap never recompiles the hot path.

        The install is measured end-to-end (``two_timescale.atomic_swap``
        blocks until the new tables are device-ready, Eq. 18's semantics;
        ``measure_install_time`` takes the wall clock) and the record flags
        a ``t_cp`` budget violation instead of silently succeeding.
        """
        from repro.core.two_timescale import atomic_swap, measure_install_time

        old = self.rules
        new, source = resolve_swap(old, ruleset, weights, weight_spec, delta)
        installed = {}

        def _install():
            installed["rules"] = atomic_swap(old, new)
            if self._int_plan is not None:
                # re-lower the soft-rule weight column so the int score path
                # reads the NEW table; counted inside the measured install —
                # the Eq. 18 budget covers everything the swap deploys
                from repro.compile.int_lowering import requantize_rule_weights

                installed["tables"] = {
                    **self._int_tables,
                    "rule_w": requantize_rule_weights(
                        self._int_plan, installed["rules"].weights
                    ),
                }
            return installed["rules"]

        dt = measure_install_time(_install)
        self.rules = installed["rules"]
        if "tables" in installed:
            self._int_tables = installed["tables"]
        ok = (
            hardware_model.install_time_ok(dt, self.fcfg.t_cp_s)
            if self.fcfg.t_cp_s
            else True
        )
        rec = SwapRecord(
            tick=self._tick, install_s=dt, churn_ok=ok,
            t_cp_s=self.fcfg.t_cp_s, source=source,
        )
        self.swap_history.append(rec)
        return rec
