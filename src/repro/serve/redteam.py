"""Red-team trust gate: replay adversarial campaigns, prove the invariants
(DESIGN.md §18).

The paper's trust claim (§3.5–3.6) is that the symbolic guarantees are
*hard*: a TCAM hard-veto pins S = 1.0 and never un-fires, no matter what
the neural path or the slow-timescale adaptation does.  Until now that was
exercised only by unit tests on generator-shaped traffic.  This harness
replays every registered :mod:`~repro.data.campaigns` campaign — and the
committed sample trace — through deployed engines and *measures* the claim:

* **static** — tables frozen at deploy time (the blind baseline),
* **oracle** — phase-correct rules handed over at every boundary (the
  perfect-foreknowledge upper bound),
* **adaptive** — an :class:`~repro.serve.adaptive_loop.AdaptiveLoop`
  closing the detect → relearn → audited-delta → measured-install loop,

and asserts, per campaign:

1. **No hard-veto flips.**  Once any packet of a flow is vetoed, every
   later packet of that flow is vetoed — across rule swaps, adaptation
   installs and phase boundaries, in all three modes.
2. **S = 1.0 pinning.**  ``trust == 1.0`` exactly on the vetoed packets,
   strictly below elsewhere, every batch.
3. **Recovery.**  Adaptive per-phase trust-decision accuracy (veto verdict
   vs ground-truth anomaly label) reaches >= ``recovery_floor`` (default
   90%) of the oracle's, phase by phase.
4. **Eq. 18 compliance.**  Every adaptation install lands inside the
   ``t_cp`` budget (violators must have been rolled back), reported with
   installs/hour.
5. **No evictions** during the replay — the sticky-veto guarantee is
   scoped to table-resident flows (§3.5), so the gate sizes the table to
   keep every campaign flow resident and asserts it stayed that way.

Each campaign yields a JSON scorecard; the CLI writes the set as one
artifact and exits non-zero if any gate check fails — this is the CI
red-team lane, not just a report.

    PYTHONPATH=src python -m repro.serve.redteam --fast --out scorecard.json
    PYTHONPATH=src python -m repro.serve.redteam --campaigns all --out all.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

_MODES = ("static", "oracle", "adaptive")

# harness-default detector sensitivity (campaigns may override per threat
# model): the serving-tier policy with a faster cooldown, so short CI
# campaigns still fit several control-plane epochs
DEFAULT_POLICY: Dict[str, float] = dict(
    warmup_ticks=2, cooldown_ticks=4, sig_novelty=0.05, churn_shift=0.12,
)


def split_policy(campaign_policy) -> Tuple[Dict[str, float], Dict[str, float]]:
    """Route a campaign's policy overrides onto the two tuning surfaces:
    keys naming :class:`~repro.serve.adaptive_loop.DriftPolicy` fields
    (trigger thresholds) vs :class:`~repro.serve.adaptive_loop
    .AdaptiveLoopConfig` fields (EWMA rates, relearn sensitivity).  The
    DriftPolicy side starts from :data:`DEFAULT_POLICY`."""
    import dataclasses as dc

    from repro.serve.adaptive_loop import AdaptiveLoopConfig, DriftPolicy

    drift_fields = {f.name for f in dc.fields(DriftPolicy)}
    loop_fields = {f.name for f in dc.fields(AdaptiveLoopConfig)}
    drift, loop_cfg = dict(DEFAULT_POLICY), {}
    for k, v in dict(campaign_policy).items():
        if k in drift_fields:
            drift[k] = v
        elif k in loop_fields:
            loop_cfg[k] = v
        else:
            raise ValueError(
                f"campaign policy key {k!r} matches neither DriftPolicy "
                f"nor AdaptiveLoopConfig fields"
            )
    return drift, loop_cfg


class RedTeamError(AssertionError):
    """A red-team gate check failed (the scorecard names the violation)."""


@dataclasses.dataclass(frozen=True)
class RedTeamConfig:
    recovery_floor: float = 0.9  # adaptive/oracle per-phase accuracy bar
    capacity: int = 4096  # sized so no campaign evicts (precondition)
    lanes: int = 128
    backend: Optional[str] = None  # None -> the program pass's default
    sync: bool = True  # inline control plane (deterministic scorecards)
    record_history: bool = False  # keep per-batch veto/pred (golden test)


@dataclasses.dataclass
class PhaseReport:
    """Per-phase slice of one campaign scorecard."""

    phase: int
    kind: str
    batches: int
    sig_rotation: int
    packets: int = 0
    anomalous: int = 0
    veto_rate: Dict[str, float] = dataclasses.field(default_factory=dict)
    accuracy: Dict[str, float] = dataclasses.field(default_factory=dict)
    recovery: float = 0.0  # adaptive accuracy / oracle accuracy


@dataclasses.dataclass
class CampaignScorecard:
    campaign: str
    goal: str
    benign: bool
    phases: List[PhaseReport]
    # invariant counters, summed over all replayed modes
    pinning_violations: int = 0
    veto_flips: int = 0
    evictions: int = 0
    # adaptation accounting (the adaptive replay)
    triggers: int = 0
    installs: int = 0
    installs_within_t_cp: int = 0
    rollbacks: int = 0
    t_cp_s: float = 0.0
    installs_per_hour: float = 0.0
    wall_s: float = 0.0
    packets: int = 0
    recovery_floor: float = 0.0
    policy: Dict[str, float] = dataclasses.field(default_factory=dict)
    passed: bool = False
    failures: List[str] = dataclasses.field(default_factory=list)
    history: Optional[List[Dict[str, List[int]]]] = None

    def as_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if self.history is None:
            d.pop("history")
        return d


class TrustInvariantTracker:
    """Streaming observer of the §3.5 hard guarantees.

    ``observe`` must see every ingested batch of one replay, in order.
    Flips are counted per *flow*: a flow whose veto bit was ever set and
    whose later packet comes back un-vetoed is a broken sticky veto."""

    def __init__(self):
        self._vetoed_once: Dict[int, bool] = {}
        self.pinning_violations = 0
        self.veto_flips = 0
        self.packets = 0
        self.vetoed_packets = 0

    def observe(self, flow_ids: np.ndarray, out: Dict[str, np.ndarray]) -> None:
        trust = np.asarray(out["trust"])
        vetoed = np.asarray(out["vetoed"], bool)
        self.packets += int(vetoed.shape[0])
        self.vetoed_packets += int(vetoed.sum())
        # Eq. 15 pinning, both directions: vetoed <=> trust exactly 1.0
        self.pinning_violations += int(np.sum((trust == 1.0) != vetoed))
        for fid, v in zip(
            np.asarray(flow_ids).tolist(), vetoed.tolist()
        ):
            if self._vetoed_once.get(fid, False) and not v:
                self.veto_flips += 1
            elif v:
                self._vetoed_once[fid] = True


def _build_classifier(vocab_size: int = 512):
    """The harness's fixed tiny deployment (same shape as the adaptive
    example / conformance tiers) — deterministic in PRNGKey(0)."""
    import dataclasses as dc

    import jax

    from repro.configs import smoke_config
    from repro.train import classifier as C

    arch = dc.replace(
        smoke_config("chimera-dataplane"), n_layers=2, d_model=32, d_ff=64,
        n_heads=2, n_kv_heads=2, d_head=16, vocab_size=vocab_size,
    )
    ccfg = C.ClassifierConfig(arch=arch, n_classes=8, marker_base=256)
    params, _ = C.init_classifier(ccfg, jax.random.PRNGKey(0))
    return ccfg, params


def _compile_for_signature(ccfg, params, signature, backend):
    import jax.numpy as jnp

    from repro.compile import compile_program
    from repro.train import classifier as C

    return compile_program(
        ccfg, params,
        rules=lambda c: C.default_rules(c, jnp.asarray(signature)),
        backend=backend,
    )


def _deploy(program, cfg: RedTeamConfig):
    from repro.serve.deploy import DeploySpec
    from repro.serve.flow_engine import FlowEngineConfig

    return program.deploy(DeploySpec(
        flow=FlowEngineConfig(capacity=cfg.capacity, lanes=cfg.lanes),
    ))


# --------------------------------------------------------------------------
# campaign replay
# --------------------------------------------------------------------------

def _replay_campaign_mode(campaign, cfg: RedTeamConfig, mode: str):
    """One full campaign cycle through one deployment mode.  Returns
    (per-phase correct/total/veto counts, tracker, loop|None, wall_s,
    history)."""
    from repro.serve.adaptive_loop import (
        AdaptiveLoop, AdaptiveLoopConfig, DriftPolicy,
    )

    ccfg, params = _build_classifier()
    sc = campaign.scenario()
    program = _compile_for_signature(
        ccfg, params, sc.phase_anomaly_signature(0), cfg.backend
    )
    eng = _deploy(program, cfg)
    loop = None
    if mode == "adaptive":
        drift, loop_cfg = split_policy(campaign.policy)
        loop = AdaptiveLoop(
            eng,
            policy=DriftPolicy(**drift),
            cfg=AdaptiveLoopConfig(sync=cfg.sync, **loop_cfg),
        )
    n_phases = len(campaign.phases)
    correct = np.zeros(n_phases)
    total = np.zeros(n_phases)
    vetoes = np.zeros(n_phases)
    anom = np.zeros(n_phases)
    tracker = TrustInvariantTracker()
    history: List[Dict[str, List[int]]] = []
    cur = 0
    t0 = time.perf_counter()
    for _ in range(sc.batches_per_cycle):
        ph = sc.phase_index()
        if mode == "oracle" and ph != cur:
            oracle = _compile_for_signature(
                ccfg, params, sc.phase_anomaly_signature(ph), cfg.backend
            )
            eng.swap_tables(ruleset=oracle.rules)
            cur = ph
        b = sc.next_batch()
        out = (loop or eng).ingest(b["flow_ids"], b["tokens"])
        tracker.observe(b["flow_ids"], out)
        correct[ph] += int((out["vetoed"] == b["anomalous"]).sum())
        total[ph] += len(out["vetoed"])
        vetoes[ph] += int(np.asarray(out["vetoed"]).sum())
        anom[ph] += int(np.asarray(b["anomalous"]).sum())
        if cfg.record_history:
            history.append({
                "vetoed": np.asarray(out["vetoed"], np.int64).tolist(),
                "pred": np.asarray(out["pred"], np.int64).tolist(),
            })
    wall = time.perf_counter() - t0
    if loop is not None:
        loop.close()
    evicted = int(eng.stats.flows_evicted)
    return correct, total, vetoes, anom, tracker, loop, wall, evicted, history


def run_campaign(campaign, cfg: Optional[RedTeamConfig] = None) -> CampaignScorecard:
    """Replay one campaign through all three modes and score the gate."""
    cfg = cfg if cfg is not None else RedTeamConfig()
    drift, loop_cfg = split_policy(campaign.policy)
    policy = {**drift, **loop_cfg}
    card = CampaignScorecard(
        campaign=campaign.name, goal=campaign.goal, benign=campaign.benign,
        phases=[
            PhaseReport(phase=i, kind=p.kind, batches=p.batches,
                        sig_rotation=p.sig_rotation)
            for i, p in enumerate(campaign.phases)
        ],
        recovery_floor=cfg.recovery_floor,
        policy={k: float(v) for k, v in policy.items()},
    )
    acc: Dict[str, np.ndarray] = {}
    for mode in _MODES:
        (correct, total, vetoes, anom, tracker, loop, wall, evicted,
         history) = _replay_campaign_mode(campaign, cfg, mode)
        acc[mode] = correct / np.maximum(total, 1)
        card.pinning_violations += tracker.pinning_violations
        card.veto_flips += tracker.veto_flips
        card.evictions += evicted
        for i, rep in enumerate(card.phases):
            rep.veto_rate[mode] = round(
                float(vetoes[i] / max(total[i], 1)), 6
            )
            rep.accuracy[mode] = round(float(acc[mode][i]), 6)
            if mode == "static":  # identical traffic in every mode
                rep.packets = int(total[i])
                rep.anomalous = int(anom[i])
        if mode == "adaptive":
            card.triggers = len(loop.history)
            card.installs = loop.installs
            card.installs_within_t_cp = loop.installs_within_budget
            card.rollbacks = sum(r.rolled_back for r in loop.history)
            card.t_cp_s = float(loop.t_cp_s)
            card.wall_s = round(wall, 3)
            card.packets = tracker.packets
            card.installs_per_hour = round(loop.installs / wall * 3600.0, 1)
            if cfg.record_history:
                card.history = history
    for rep in card.phases:
        oracle_acc = max(acc["oracle"][rep.phase], 1e-9)
        rep.recovery = round(float(acc["adaptive"][rep.phase] / oracle_acc), 6)

    # ---- the gate -----------------------------------------------------
    f = card.failures
    if card.pinning_violations:
        f.append(f"S=1.0 pinning violated on "
                 f"{card.pinning_violations} packet(s)")
    if card.veto_flips:
        f.append(f"hard-veto invariant flipped on "
                 f"{card.veto_flips} flow occurrence(s)")
    if card.evictions:
        f.append(f"{card.evictions} eviction(s): replay precondition broken "
                 f"(grow RedTeamConfig.capacity)")
    for rep in card.phases:
        if rep.recovery < cfg.recovery_floor:
            f.append(
                f"phase {rep.phase} ({rep.kind}"
                f"{f', rot {rep.sig_rotation}' if rep.sig_rotation else ''}): "
                f"recovery {rep.recovery:.3f} < floor {cfg.recovery_floor}"
            )
    if card.installs != card.installs_within_t_cp:
        f.append(
            f"{card.installs - card.installs_within_t_cp} install(s) "
            f"outside the Eq. 18 t_cp budget ({card.t_cp_s:g}s) "
            f"survived without rollback"
        )
    if not campaign.benign and campaign.attack_phases and not card.installs:
        f.append("attack campaign triggered no adaptation install "
                 "(the loop never saw the rotation)")
    card.passed = not f
    return card


# --------------------------------------------------------------------------
# trace replay check
# --------------------------------------------------------------------------

def run_trace(trace_path: Optional[str] = None,
              cfg: Optional[RedTeamConfig] = None,
              packets_per_batch: int = 128) -> CampaignScorecard:
    """Replay a recorded trace (default: the committed sample) through a
    static deployment compiled against the trace's labeled signature, and
    hold the same hard invariants.  There is no drift schedule in a single
    trace, so the oracle IS the static deployment: the scorecard's
    recovery is static-accuracy coverage, and the adaptation fields stay
    zero."""
    from repro.data import traces as TR

    cfg = cfg if cfg is not None else RedTeamConfig()
    trace = TR.load_trace(trace_path or TR.SAMPLE_TRACE)
    sc = TR.TraceReplayScenario(trace, packets_per_batch=packets_per_batch)
    ccfg, params = _build_classifier(vocab_size=trace.meta.vocab_size)
    program = _compile_for_signature(
        ccfg, params, sc.anomaly_signature, cfg.backend
    )
    eng = _deploy(program, cfg)
    tracker = TrustInvariantTracker()
    correct = total = 0
    t0 = time.perf_counter()
    for b in sc:
        out = eng.ingest(b["flow_ids"], b["tokens"])
        tracker.observe(b["flow_ids"], out)
        correct += int((out["vetoed"] == b["anomalous"]).sum())
        total += len(out["vetoed"])
    wall = time.perf_counter() - t0
    acc = correct / max(total, 1)
    card = CampaignScorecard(
        campaign=f"trace-replay:{trace_path or 'sample'}",
        goal="recorded-traffic replay: invariants under real arrival "
             "processes",
        benign=False,
        phases=[PhaseReport(
            phase=0, kind="trace", batches=sc.batches_per_cycle,
            sig_rotation=0, packets=total,
            anomalous=int(trace.anomalous.sum()),
            veto_rate={"static": round(tracker.vetoed_packets / max(total, 1), 6)},
            accuracy={"static": round(acc, 6)},
            recovery=1.0,
        )],
        pinning_violations=tracker.pinning_violations,
        veto_flips=tracker.veto_flips,
        evictions=int(eng.stats.flows_evicted),
        wall_s=round(wall, 3),
        packets=total,
        recovery_floor=cfg.recovery_floor,
    )
    f = card.failures
    if card.pinning_violations:
        f.append(f"S=1.0 pinning violated on "
                 f"{card.pinning_violations} packet(s)")
    if card.veto_flips:
        f.append(f"hard-veto invariant flipped on "
                 f"{card.veto_flips} flow occurrence(s)")
    if card.evictions:
        f.append(f"{card.evictions} eviction(s) during trace replay")
    if not 0 < tracker.vetoed_packets < total:
        f.append("trace replay must exercise both veto branches "
                 "(all-or-none vetoes make the invariant checks vacuous)")
    card.passed = not f
    return card


# --------------------------------------------------------------------------
# the gate CLI
# --------------------------------------------------------------------------

def run_redteam(
    names: Optional[List[str]] = None,
    cfg: Optional[RedTeamConfig] = None,
    include_trace: bool = True,
    trace_path: Optional[str] = None,
) -> List[CampaignScorecard]:
    from repro.data.campaigns import get_campaign, list_campaigns

    cfg = cfg if cfg is not None else RedTeamConfig()
    cards = []
    for name in (names if names is not None else list_campaigns()):
        cards.append(run_campaign(get_campaign(name), cfg))
    if include_trace:
        cards.append(run_trace(trace_path, cfg))
    return cards


def _summary_line(card: CampaignScorecard) -> str:
    worst = min((p.recovery for p in card.phases), default=1.0)
    return (
        f"{'PASS' if card.passed else 'FAIL'}  {card.campaign:24s} "
        f"pkts={card.packets:<6d} flips={card.veto_flips} "
        f"pin_viol={card.pinning_violations} "
        f"installs={card.installs} ({card.installs_within_t_cp} in t_cp, "
        f"{card.rollbacks} rolled back) min_recovery={worst:.3f}"
    )


def main(argv: Optional[List[str]] = None) -> int:
    from repro.data.campaigns import SMOKE_CAMPAIGN, list_campaigns

    ap = argparse.ArgumentParser(
        description="red-team trust gate over the campaign library")
    ap.add_argument("--campaigns", default="all",
                    help="'all' or comma-separated campaign names")
    ap.add_argument("--fast", action="store_true",
                    help=f"CI fast lane: only the {SMOKE_CAMPAIGN!r} "
                         f"campaign + the sample-trace replay")
    ap.add_argument("--list", action="store_true",
                    help="list registered campaigns and exit")
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the scorecards as a JSON artifact")
    ap.add_argument("--backend", default=None,
                    help="kernel backend override (xla | reference | "
                         "pallas-interpret | int-emulation | ...)")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="background control plane (scorecards then depend "
                         "on host timing; the gate only runs sync)")
    ap.add_argument("--recovery-floor", type=float, default=0.9)
    ap.add_argument("--skip-trace", action="store_true",
                    help="skip the sample-trace replay check")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="replay this trace file instead of the sample")
    args = ap.parse_args(argv)

    if args.list:
        from repro.data.campaigns import get_campaign

        for name in list_campaigns():
            c = get_campaign(name)
            kinds = ",".join(
                f"{p.kind}:{p.batches}"
                + (f":rot{p.sig_rotation}" if p.sig_rotation else "")
                for p in c.phases
            )
            print(f"{name:20s} [{'benign' if c.benign else 'attack'}] "
                  f"{c.batches} batches  {kinds}\n    {c.goal}")
        return 0

    if args.fast:
        names: Optional[List[str]] = [SMOKE_CAMPAIGN]
    elif args.campaigns == "all":
        names = None
    else:
        names = [n.strip() for n in args.campaigns.split(",") if n.strip()]

    cfg = RedTeamConfig(
        recovery_floor=args.recovery_floor,
        backend=args.backend,
        sync=not args.use_async,
    )
    cards = run_redteam(
        names, cfg, include_trace=not args.skip_trace, trace_path=args.trace
    )

    for card in cards:
        print(_summary_line(card))
        for msg in card.failures:
            print(f"        {msg}")
    if args.out:
        payload = {
            "schema": "redteam-scorecard-v1",
            "recovery_floor": cfg.recovery_floor,
            "sync": cfg.sync,
            "passed": all(c.passed for c in cards),
            "scorecards": [c.as_dict() for c in cards],
        }
        with open(args.out, "w") as fp:
            json.dump(payload, fp, indent=2, sort_keys=True)
            fp.write("\n")
        print(f"scorecards written to {args.out}")

    failed = [c.campaign for c in cards if not c.passed]
    if failed:
        print(f"red-team gate FAILED: {', '.join(failed)}", file=sys.stderr)
        return 1
    print(f"red-team gate OK: {len(cards)} scorecard(s) green "
          f"(zero veto flips, zero pinning violations, recovery >= "
          f"{cfg.recovery_floor:g}, all installs within t_cp)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
