"""Sharded multi-device flow serving (DESIGN.md §12).

Scale-out of the :class:`~repro.serve.flow_engine.FlowEngine` flow table:
the flow-keyed Chimera state is partitioned into ``num_shards`` independent
shards, one per device on the ``data`` axis of a :func:`repro.launch.mesh
.make_flow_mesh` mesh, executed together under ``shard_map``.  Aggregate
resident-flow capacity and packets/sec scale with device count while every
per-flow guarantee of the single-device engine is preserved verbatim:

* **Routing** is deterministic and batch-independent —
  ``flow_shard(fid) % num_shards`` (a fixed splitmix64 mix, stable across
  processes and batch resizes), so a flow's packets always land on the
  same shard and its state never migrates.
* **Per-shard tables**: each shard owns a
  :class:`~repro.serve.flow_engine.FlowTableDirectory` (LRU + idle
  eviction, bounded capacity) and its slice of the slot-batched device
  state.  Sticky TCAM veto bits live in the shard that owns the flow.
* **One batched hot path**: ``ingest`` scatters each arrival round to its
  owner shards as a single ``(num_shards, lanes)`` launch of the *same*
  :func:`~repro.serve.flow_engine.make_flow_step` function the
  single-device engine jits — one ``shard_map``-ped call per round, one
  host gather of the stacked outputs, no per-shard host round trips.
  Because the per-lane math is the identical traced function, sharded
  replay is bit-identical to single-device replay of the same traffic.
* **Replicated control plane**: params and rule tables are placed
  replicated over the mesh; :meth:`ShardedFlowEngine.swap_tables` installs
  a new RuleSet / quantized weight table / audited ``ProgramDelta``
  atomically on *all* shards in one measured install, so the Eq. 18
  ``t_cp`` accounting covers the sharded case end-to-end.
* **Per-shard budgets**: the Eq. 11 flow-table byte budget is enforced per
  shard at construction; aggregate capacity is reported as
  ``num_shards x per-shard budget`` (and recorded in the program's
  :class:`~repro.compile.ledger.ResourceLedger` on deploy).
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import hardware_model
from repro.core import symbolic
from repro.core.hardware_model import DEFAULT_DATAPLANE
from repro.data.pipeline import arrival_rounds, flow_shard
from repro.models import model as M
from repro.serve.flow_engine import (
    FlowEngineConfig,
    FlowStats,
    FlowTableDirectory,
    SwapRecord,
    make_flow_step,
    resolve_swap,
)
from repro.train import classifier as C


class ShardedFlowEngine:
    """Flow-table streaming inference partitioned across a device mesh.

    Drop-in for :class:`~repro.serve.flow_engine.FlowEngine` (same
    ``ingest`` / ``flow_scores`` / ``swap_tables`` / stats surface) with
    the table sharded over the mesh ``data`` axis.  ``fcfg.capacity`` and
    ``fcfg.state_budget_bytes`` are *per shard*; aggregate capacity is
    ``num_shards * fcfg.capacity``.
    """

    def __init__(
        self,
        ccfg: C.ClassifierConfig,
        params,
        rules: symbolic.RuleSet,
        fcfg: FlowEngineConfig = FlowEngineConfig(),
        *,
        mesh=None,
        num_shards: Optional[int] = None,
    ):
        from repro.kernels.dispatch import apply_kernel_backend
        from repro.launch.mesh import make_flow_mesh, shard_map_compat

        if fcfg.fused:
            # the fused flow_ingest megakernel is a single-device launch;
            # silently falling back to the per-round path here would make
            # `fused=True` a no-op — refuse loudly instead of quietly
            # serving at per-round throughput
            raise NotImplementedError(
                "FlowEngineConfig(fused=True) has no sharded implementation "
                "(the fused flow_ingest launch is single-device). Deploy "
                "with DeploySpec(engine='flow', flow=fcfg) for fused "
                "ingest, or drop fused=True to shard the per-round path."
            )
        if mesh is None:
            mesh = make_flow_mesh(num_shards)
        if "data" not in mesh.axis_names:
            raise ValueError(
                f"flow serving shards over 'data'; mesh axes are {mesh.axis_names}"
            )
        S = int(mesh.shape["data"])
        if math.prod(mesh.devices.shape) != S:
            raise ValueError(
                "flow tables shard only over 'data'; every other mesh axis "
                f"must have size 1 (got mesh shape {dict(mesh.shape)})"
            )
        if num_shards is not None and num_shards != S:
            raise ValueError(
                f"num_shards={num_shards} but the mesh 'data' axis has {S} devices"
            )
        self.mesh = mesh
        self.num_shards = S

        arch, self.backend = apply_kernel_backend(ccfg.arch, fcfg.backend)
        self.ccfg = dataclasses.replace(ccfg, arch=arch)
        self.fcfg = fcfg
        self.stats = FlowStats()
        self.swap_history: List[SwapRecord] = []
        self.program = None  # set by from_program

        # int-emulation: the lowered plan/tables are pure functions of
        # (ccfg, params, rules, horizon) — flow-independent, so they shard
        # trivially by REPLICATION: every device carries the same int32
        # tables (they ride the jitted step's replicated rules argument,
        # exactly like the float RuleSet), while only the flow state rows
        # split over 'data'.
        self._int_plan = None
        self._int_tables = None
        self._int_entries: List = []
        if self.backend == "int-emulation":
            from repro.compile.int_lowering import lower_scores
            from repro.compile.ledger import ResourceLedger

            self._int_plan, self._int_tables, self._int_entries = lower_scores(
                self.ccfg, params, rules, horizon=fcfg.horizon
            )
            deploy_ledger = ResourceLedger()
            deploy_ledger.extend(self._int_entries)
            deploy_ledger.raise_if_over()

        self._replicated = NamedSharding(mesh, P())
        self._row_sharded = NamedSharding(mesh, P("data"))
        self.params = jax.device_put(params, self._replicated)
        self.rules = jax.device_put(rules, self._replicated)
        if self._int_tables is not None:
            self._int_tables = jax.device_put(self._int_tables, self._replicated)

        # per-shard slot-batched state (capacity real slots + one scratch
        # slot absorbing padding lanes), stacked on a leading shard axis
        # that shard_map splits over 'data'
        self._n_slots = fcfg.capacity + 1

        def shardwise(c):
            return jax.device_put(
                jnp.broadcast_to(c[None], (S,) + c.shape), self._row_sharded
            )

        caches = M.init_caches(
            arch, self._n_slots, fcfg.max_flow_tokens, dtype=jnp.float32
        )
        self.caches = jax.tree_util.tree_map(shardwise, caches)
        W, d = self.ccfg.sig_words, arch.d_model
        self.positions = shardwise(jnp.zeros((self._n_slots,), jnp.int32))
        self.sig = shardwise(jnp.zeros((self._n_slots, W), jnp.uint32))
        hs_dtype = jnp.int32 if self._int_plan is not None else jnp.float32
        self.hidden_sum = shardwise(jnp.zeros((self._n_slots, d), hs_dtype))
        self.vetoed = shardwise(jnp.zeros((self._n_slots,), bool))

        # one host-side directory per shard: allocation, LRU and idle
        # eviction are shard-local (a flow only ever competes for slots
        # with flows routed to the same shard)
        self.tables = [FlowTableDirectory(fcfg.capacity) for _ in range(S)]
        self._tick = 0

        # Eq. 11 budget, enforced PER SHARD at construction: each device's
        # table slice must fit the per-shard SRAM budget on its own
        budget = fcfg.state_budget_bytes or DEFAULT_DATAPLANE.sram_total_bits // 8
        self.state_budget_bytes = budget  # per shard
        hardware_model.check_flow_table_budget(
            self._n_slots, self.per_flow_state_bytes(), budget
        )

        step = make_flow_step(self.ccfg, self._n_slots, int_plan=self._int_plan)

        def shard_step(params, rules, caches, positions, sig, hidden_sum,
                       vetoed, idx, tokens, fresh):
            # inside shard_map every table arg carries a leading shard axis
            # of size 1 (this device's rows); params/rules arrive replicated
            def sq(t):
                return jax.tree_util.tree_map(lambda x: x[0], t)

            caches, positions, sig, hidden_sum, vetoed, out = step(
                params, rules, sq(caches), positions[0], sig[0],
                hidden_sum[0], vetoed[0], idx[0], tokens[0], fresh[0],
            )

            def ex(t):
                return jax.tree_util.tree_map(lambda x: x[None], t)

            return (ex(caches), positions[None], sig[None], hidden_sum[None],
                    vetoed[None], ex(out))

        smap = shard_map_compat(
            shard_step, mesh,
            in_specs=(P(), P(), P("data"), P("data"), P("data"), P("data"),
                      P("data"), P("data"), P("data"), P("data")),
            out_specs=(P("data"),) * 6,
        )
        self._jit_step = jax.jit(smap, donate_argnums=(2, 3, 4, 5, 6))

    def jit_entry_points(self):
        """Named jitted hot-path callables, for the retrace sentry."""
        return {"step": self._jit_step}

    # ------------------------------------------------------------------
    # compiled-program deployment (deprecated shim — DESIGN.md §17.4)
    # ------------------------------------------------------------------
    @classmethod
    def from_program(
        cls,
        program,
        fcfg: FlowEngineConfig = FlowEngineConfig(),
        *,
        mesh=None,
        num_shards: Optional[int] = None,
    ) -> "ShardedFlowEngine":
        """Deprecated: deploy through the one front door instead —
        ``program.deploy(DeploySpec(engine="sharded", flow=fcfg,
        num_shards=..., mesh=...))``."""
        warnings.warn(
            "ShardedFlowEngine.from_program is deprecated; use "
            "DataplaneProgram.deploy(DeploySpec(engine='sharded', "
            "flow=fcfg, num_shards=..., mesh=...)) — the shim will be "
            "removed one release cycle after DeploySpec landed "
            "(DESIGN.md §17.4)",
            DeprecationWarning, stacklevel=2,
        )
        from repro.serve.deploy import build_sharded_engine

        return build_sharded_engine(
            program, fcfg, mesh=mesh, num_shards=num_shards
        )

    # ------------------------------------------------------------------
    # routing + state accounting
    # ------------------------------------------------------------------
    def shard_of(self, fid: int) -> int:
        """Owner shard of a flow ID (deterministic, batch-independent)."""
        return int(flow_shard([fid], self.num_shards)[0])

    def _step_rules(self):
        """The replicated ``rules`` argument of the jitted step: the packed
        RuleSet, paired with the lowered int tables under int-emulation."""
        if self._int_plan is not None:
            return (self.rules, self._int_tables)
        return self.rules

    def per_flow_state_bytes(self) -> int:
        """Bytes of one flow-table entry (identical to the single-device
        engine's: Eq. 11/13 decode state + classifier aggregates)."""
        denom = self.num_shards * self._n_slots
        cache_bytes = sum(
            leaf.nbytes // denom
            for leaf in jax.tree_util.tree_leaves(self.caches)
        )
        aux = (
            self.sig.nbytes + self.hidden_sum.nbytes
            + self.positions.nbytes + self.vetoed.nbytes
        ) // denom
        return cache_bytes + aux + 8  # + host LRU timestamp

    def shard_state_bytes(self) -> int:
        """Allocated table bytes on ONE shard (what the per-shard Eq. 11
        budget check is held against)."""
        return hardware_model.flow_table_bytes(
            self._n_slots, self.per_flow_state_bytes()
        )

    def resident_state_bytes(self) -> int:
        """Aggregate allocated table bytes across all shards."""
        return self.num_shards * self.shard_state_bytes()

    @property
    def aggregate_capacity(self) -> int:
        return self.num_shards * self.fcfg.capacity

    @property
    def aggregate_state_budget_bytes(self) -> int:
        return self.num_shards * self.state_budget_bytes

    @property
    def resident_flows(self) -> int:
        return sum(t.resident for t in self.tables)

    def resident_flows_per_shard(self) -> List[int]:
        return [t.resident for t in self.tables]

    def flow_ids(self) -> List[int]:
        return [f for t in self.tables for f in t.slot_of]

    # ------------------------------------------------------------------
    # eviction (shard-local, aggregated stats)
    # ------------------------------------------------------------------
    def reset(self) -> None:
        """Clear every shard's flow table without touching the jitted step
        (device state is lazily zeroed on slot reuse, as single-device)."""
        for t in self.tables:
            t.reset()
        self._tick = 0
        self.stats = FlowStats()

    def evict(self, fid: int) -> bool:
        return self.tables[self.shard_of(fid)].evict(fid)

    def evict_idle(self) -> int:
        if not self.fcfg.idle_timeout:
            return 0
        horizon = self._tick - self.fcfg.idle_timeout
        n = 0
        for t in self.tables:
            for fid in t.idle_victims(horizon):
                t.evict(fid)
                self.stats.flows_evicted_idle += 1
                n += 1
        return n

    # ------------------------------------------------------------------
    # ingest
    # ------------------------------------------------------------------
    def ingest(self, flow_ids: np.ndarray, tokens: np.ndarray) -> Dict[str, np.ndarray]:
        """Stream one batch of packet arrivals through the sharded table.

        Same contract as :meth:`FlowEngine.ingest` — per-packet outputs
        aligned with the input arrival order, same-flow packets serialized,
        distinct flows vectorized — except each arrival round launches ONE
        ``(num_shards, lanes)`` shard_map-ped step covering every shard.
        """
        flow_ids = np.asarray(flow_ids)
        tokens = np.asarray(tokens, np.int32)
        Pk, pkt_len = tokens.shape
        assert flow_ids.shape == (Pk,), (flow_ids.shape, Pk)
        self._tick += 1
        self.stats.ticks += 1
        owners = flow_shard(flow_ids, self.num_shards)

        # touch resident flows in this batch BEFORE the idle sweep and any
        # allocation (same victim-selection contract as the single-device
        # engine: flows with packets pending here are not eviction victims
        # unless their shard is over-subscribed within this very batch)
        for fid, own in zip(flow_ids.tolist(), owners.tolist()):
            self.tables[own].touch(fid, self._tick)
        self.evict_idle()

        slots = np.empty((Pk,), np.int32)
        fresh = np.zeros((Pk,), bool)
        for i, (fid, own) in enumerate(zip(flow_ids.tolist(), owners.tolist())):
            slot, fr, evicted = self.tables[own].slot_for(fid, self._tick)
            slots[i], fresh[i] = slot, fr
            if fr:
                self.stats.flows_created += 1
            if evicted:
                self.stats.flows_evicted_lru += 1

        # shard-local arrival rounds, flattened to fixed-width lane chunks;
        # chunk k of every shard rides the same device launch
        lanes = self.fcfg.lanes
        scratch = self.fcfg.capacity
        per_shard_chunks: List[List[np.ndarray]] = []
        for s in range(self.num_shards):
            pkt_idx = np.nonzero(owners == s)[0]
            chunks: List[np.ndarray] = []
            for round_lanes in arrival_rounds(slots[pkt_idx].tolist()):
                sel = pkt_idx[round_lanes]
                for c0 in range(0, len(sel), lanes):
                    chunks.append(sel[c0 : c0 + lanes])
            per_shard_chunks.append(chunks)
        n_steps = max((len(c) for c in per_shard_chunks), default=0)

        out_trust = np.empty((Pk,), np.float32)
        out_veto = np.empty((Pk,), bool)
        out_pred = np.empty((Pk,), np.int32)
        out_s_nn = np.empty((Pk,), np.float32)
        out_s_sym = np.empty((Pk,), np.float32)
        out_sig = np.zeros((Pk, self.ccfg.sig_words), np.uint32)

        for k in range(n_steps):
            idx = np.full((self.num_shards, lanes), scratch, np.int32)
            tok = np.zeros((self.num_shards, lanes, pkt_len), np.int32)
            fr = np.zeros((self.num_shards, lanes), bool)
            chunk_of: List[Optional[np.ndarray]] = [None] * self.num_shards
            for s, chunks in enumerate(per_shard_chunks):
                if k < len(chunks):
                    sel = chunks[k]
                    n = len(sel)
                    idx[s, :n] = slots[sel]
                    tok[s, :n] = tokens[sel]
                    fr[s, :n] = fresh[sel]
                    chunk_of[s] = sel
            (self.caches, self.positions, self.sig, self.hidden_sum,
             self.vetoed, out) = self._jit_step(
                self.params, self._step_rules(), self.caches, self.positions,
                self.sig, self.hidden_sum, self.vetoed,
                jax.device_put(idx, self._row_sharded),
                jax.device_put(tok, self._row_sharded),
                jax.device_put(fr, self._row_sharded),
            )
            self.stats.rounds += 1
            # ONE stacked gather per round across every shard (no per-shard
            # host round trips)
            trust = np.asarray(out["trust"], np.float32)
            hard = np.asarray(out["hard_hit"])
            pred = np.asarray(jnp.argmax(out["class_logits"], -1), np.int32)
            s_nn = np.asarray(out["s_nn"], np.float32)
            s_sym = np.asarray(out["s_sym"], np.float32)
            sig_rows = np.asarray(out["sig"])
            for s, sel in enumerate(chunk_of):
                if sel is None:
                    continue
                n = len(sel)
                out_trust[sel] = trust[s, :n]
                out_veto[sel] = hard[s, :n]
                out_pred[sel] = pred[s, :n]
                out_s_nn[sel] = s_nn[s, :n]
                out_s_sym[sel] = s_sym[s, :n]
                out_sig[sel] = sig_rows[s, :n]
        self.stats.packets += Pk
        self.stats.tokens += Pk * pkt_len
        return {
            "flow_ids": flow_ids,
            "trust": out_trust,
            "vetoed": out_veto,
            "pred": out_pred,
            "s_nn": out_s_nn,
            "s_sym": out_s_sym,
            "sig": out_sig,
        }

    # ------------------------------------------------------------------
    # per-flow snapshot
    # ------------------------------------------------------------------
    def flow_scores(self, fid: int) -> Dict[str, float]:
        """Current scores for a resident flow (control-plane read path;
        reads the owner shard's table rows)."""
        s = self.shard_of(fid)
        slot = self.tables[s].slot_of[fid]
        if self._int_plan is not None:
            from repro.compile.int_lowering import dequantize_scores
            from repro.kernels.dispatch import resolve

            out, _ = resolve("flow_score", "int-emulation")(
                self._int_plan, self._int_tables, self.rules,
                self.hidden_sum[s, slot][None], self.positions[s, slot][None],
                self.sig[s, slot][None], self.vetoed[s, slot][None],
            )
            out = dequantize_scores(self._int_plan, out)
        else:
            pooled = (
                self.hidden_sum[s, slot] / jnp.maximum(self.positions[s, slot], 1)
            )
            out, _ = C.streaming_scores(
                self.ccfg, self.params, self.rules,
                pooled[None], self.sig[s, slot][None], self.vetoed[s, slot][None],
            )
        return {
            "trust": float(out["trust"][0]),
            "vetoed": bool(out["hard_hit"][0]),
            "pred": int(jnp.argmax(out["class_logits"][0])),
            "s_nn": float(out["s_nn"][0]),
            "s_sym": float(out["s_sym"][0]),
            "tokens": int(self.positions[s, slot]),
        }

    # ------------------------------------------------------------------
    # two-timescale control-plane hook
    # ------------------------------------------------------------------
    def swap_tables(
        self,
        ruleset: Optional[symbolic.RuleSet] = None,
        weights: Optional[jax.Array] = None,
        weight_spec=None,
        delta=None,
    ) -> SwapRecord:
        """Atomically install new compiled tables on EVERY shard (§3.6).

        Same request surface as :meth:`FlowEngine.swap_tables` (raw
        RuleSet / weight table, or an audited ``ProgramDelta``), resolved
        through the shared :func:`resolve_swap` shape check.  The install
        replicates the new tables to all mesh devices inside one measured
        ``atomic_swap`` — ``measure_install_time`` only returns once every
        shard's copy is device-ready, so the recorded ``install_s`` (and
        its Eq. 18 ``t_cp`` verdict) covers the whole sharded install, not
        the first device.
        """
        from repro.core.two_timescale import atomic_swap, measure_install_time

        old = self.rules
        new, source = resolve_swap(old, ruleset, weights, weight_spec, delta)
        installed = {}

        def _install():
            repl = jax.device_put(new, self._replicated)
            installed["rules"] = atomic_swap(old, repl)
            if self._int_plan is not None:
                # re-lower the soft-rule weight column (replicated, like the
                # RuleSet) so every shard's int score path reads the NEW
                # table; counted inside the measured install — the Eq. 18
                # budget covers everything the swap deploys on every device
                from repro.compile.int_lowering import requantize_rule_weights

                installed["tables"] = {
                    **self._int_tables,
                    "rule_w": jax.device_put(
                        requantize_rule_weights(
                            self._int_plan, installed["rules"].weights
                        ),
                        self._replicated,
                    ),
                }
            return installed["rules"]

        dt = measure_install_time(_install)
        self.rules = installed["rules"]
        if "tables" in installed:
            self._int_tables = installed["tables"]
        ok = (
            hardware_model.install_time_ok(dt, self.fcfg.t_cp_s)
            if self.fcfg.t_cp_s
            else True
        )
        rec = SwapRecord(
            tick=self._tick, install_s=dt, churn_ok=ok,
            t_cp_s=self.fcfg.t_cp_s, source=source,
        )
        self.swap_history.append(rec)
        return rec
