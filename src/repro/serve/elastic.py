"""Elastic multi-shard flow serving (DESIGN.md §17).

:class:`ElasticFlowService` wraps the sharded flow engine with the three
capabilities that separate "one host's mesh" from a service:

* **Live resharding** (§17.1) — ``reshard(new_num_shards)`` quiesces
  ingest for the migrating key ranges (:func:`repro.data.pipeline
  .reshard_moves`), snapshots every resident flow row on the host (and
  through the :class:`~repro.checkpoint.Checkpointer` when a checkpoint
  directory is configured), deterministically re-routes each flow with
  :func:`repro.data.pipeline.flow_shard` under the new shard count, and
  installs the rows onto the target topology inside one measured
  ``atomic_swap``/``measure_install_time`` window.  A reshard is therefore
  just another Eq. 18-budgeted install: if it exceeds ``fcfg.t_cp_s`` it
  is ROLLED BACK (the old topology keeps serving, untouched) and the
  violation is recorded; on commit the program ledger's
  ``flow-table-sharding`` StageEntry is refreshed and an
  AdaptationRecord-style :class:`ReshardRecord` is appended to
  ``reshard_history``.  Because the copied rows feed the *same*
  :func:`~repro.serve.flow_engine.make_flow_step` traced function, a
  scenario replayed through ``reshard(2→4→2)`` is bit-identical to an
  unsharded replay in the no-eviction regime.

* **Shard fault tolerance** (§17.2) — periodic flow-state checkpoints
  (every ``ElasticConfig.checkpoint_every`` ticks) through the same
  Checkpointer the trainer uses, a per-shard
  :class:`~repro.runtime.fault_tolerance.HeartbeatMonitor`, and a
  kill-a-shard recovery path (:meth:`recover`) that reshards the
  survivors' live rows onto the shrunk mesh, restores failed-shard flows
  from the last checkpoint, and replays the bounded
  ``ElasticConfig.replay_window`` of buffered post-checkpoint batches for
  exactly the lost key ranges — so recovered flows (including sticky
  hard-veto bits) are bit-identical to a never-killed replay whenever the
  window covers the gap.

* **Admission control** (§17.3) — per-tenant flow budgets derived from
  the ResourceLedger's sharding entry (``share × aggregate capacity``,
  byte-bounded by the Eq. 11 budget), with new flows of lowest-priority
  tenants shed first under pressure.  Shed packets come back marked
  ``admitted=False`` in the ingest output (alignment preserved).

Topology cache: one engine per shard count is kept (``keep_topologies``),
so resharding back to a previously-seen count reuses its jitted step —
``jit_entry_points`` exposes every cached engine's entries under a
``shards<N>.`` namespace, which is how ``repro.analysis.gate`` audits that
a reshard never retraces steady-state ingest.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import hardware_model
from repro.core.two_timescale import atomic_swap, measure_install_time
from repro.data.pipeline import flow_shard, reshard_moves
from repro.runtime.fault_tolerance import HeartbeatMonitor, plan_shard_recovery
from repro.serve.deploy import (
    ElasticConfig,
    TenantSpec,
    _reset_deploy_stages,
    build_sharded_engine,
    record_sharding_entry,
)
from repro.serve.flow_engine import FlowEngineConfig
from repro.serve.sharded_flow_engine import ShardedFlowEngine


@dataclasses.dataclass
class ReshardRecord:
    """One elastic topology change, AdaptationRecord-style: what moved,
    how long the install took, and its Eq. 18 verdict."""

    tick: int
    old_shards: int
    new_shards: int
    reason: str  # "scale" | "recovery"
    migrated_flows: int  # resident rows carried to the new topology
    moved_flows: int  # subset whose owner shard changed (quiesced ranges)
    install_s: float  # measured wall-clock install (device-ready)
    t_cp_s: float  # the control-plane epoch the install was held to
    churn_ok: bool  # Eq. 18: install completed within the epoch
    rolled_back: bool = False
    failed_shards: Tuple[int, ...] = ()
    restored_flows: int = 0  # recovery: flows restored from checkpoint
    replayed_packets: int = 0  # recovery: bounded-window packets re-ingested
    error: Optional[str] = None

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------
# flow-state snapshots (host-side, Checkpointer-compatible pytrees)
# --------------------------------------------------------------------------

def snapshot_flow_state(eng: ShardedFlowEngine) -> Dict[str, Any]:
    """Host snapshot of every resident flow's table row, in deterministic
    (sorted fid) order: decode-cache rows, positions, packed signature,
    pooled-feature accumulator, sticky veto bit and LRU stamp.  The
    snapshot is placement-free — rows are keyed by flow ID, so they can be
    installed onto ANY shard count (:func:`install_flow_state`)."""
    entries = []
    for s, t in enumerate(eng.tables):
        for fid, slot in t.slot_of.items():
            entries.append((int(fid), s, int(slot), int(t.last_seen[slot])))
    entries.sort()
    fids = np.array([e[0] for e in entries], np.int64)
    s_idx = np.array([e[1] for e in entries], np.intp)
    sl_idx = np.array([e[2] for e in entries], np.intp)
    last_seen = np.array([e[3] for e in entries], np.int64)
    n_slots = eng._n_slots

    def rows(arr):
        return np.asarray(arr)[s_idx, sl_idx]

    def cache_rows(leaf):
        h = np.asarray(leaf)
        if h.ndim >= 3 and h.shape[2] == n_slots:
            # sharded slotted leaf (S, groups, n_slots, ...): rows (n, groups, ...)
            return h[s_idx, :, sl_idx]
        # non-slotted leaves are never written back by the flow step (see
        # make_flow_step's put()) — every shard still holds the init value,
        # so a zero-length placeholder keeps the tree structure without
        # snapshotting constants
        return np.zeros((0,), h.dtype)

    return {
        "fids": fids,
        "last_seen": last_seen,
        "positions": rows(eng.positions),
        "sig": rows(eng.sig),
        "hidden_sum": rows(eng.hidden_sum),
        "vetoed": rows(eng.vetoed),
        "caches": jax.tree_util.tree_map(cache_rows, eng.caches),
    }


def snapshot_template(eng: ShardedFlowEngine) -> Dict[str, Any]:
    """Structure-only snapshot (zero flows) — the restore target tree for
    :meth:`Checkpointer.restore` (leaf values are replaced wholesale)."""
    z = np.zeros((0,), np.int64)
    return {
        "fids": z, "last_seen": z,
        "positions": np.zeros((0,), np.int32),
        "sig": np.zeros((0, eng.ccfg.sig_words), np.uint32),
        "hidden_sum": np.zeros((0,), np.float32),
        "vetoed": np.zeros((0,), bool),
        "caches": jax.tree_util.tree_map(
            lambda leaf: np.zeros((0,), leaf.dtype), eng.caches
        ),
    }


def select_rows(snap: Dict[str, Any], mask: np.ndarray) -> Dict[str, Any]:
    """Row-filter a snapshot (cache placeholders pass through)."""

    def pick(leaf):
        leaf = np.asarray(leaf)
        if leaf.ndim >= 1 and leaf.shape[0] == len(mask):
            return leaf[mask]
        return leaf  # zero-length non-slotted placeholder

    return {
        k: (jax.tree_util.tree_map(pick, v) if k == "caches" else pick(v))
        for k, v in snap.items()
    }


def concat_snapshots(a: Dict[str, Any], b: Dict[str, Any]) -> Dict[str, Any]:
    """Merge two disjoint snapshots (recovery: live survivors + restored
    failed-shard rows)."""

    def cat(x, y):
        x, y = np.asarray(x), np.asarray(y)
        if x.ndim == 1 and x.shape[0] == 0 and y.ndim == 1 and y.shape[0] == 0:
            return x  # non-slotted placeholders
        return np.concatenate([x, y], axis=0)

    out = {
        k: cat(a[k], b[k])
        for k in ("fids", "last_seen", "positions", "sig", "hidden_sum",
                  "vetoed")
    }
    out["caches"] = jax.tree_util.tree_map(cat, a["caches"], b["caches"])
    if len(np.unique(out["fids"])) != len(out["fids"]):
        raise ValueError("concat_snapshots: overlapping flow IDs")
    return out


def install_flow_state(
    eng: ShardedFlowEngine, snap: Dict[str, Any], tick: int
) -> None:
    """Write a snapshot's rows into ``eng``'s table state (everything else
    zeroed), re-routing each flow to ``flow_shard(fid, eng.num_shards)``.

    The write is whole-table: fresh zero arrays with the snapshot rows
    scattered in, installed via :func:`atomic_swap` so the caller's
    ``measure_install_time`` window covers device-ready placement of every
    shard's rows.  Raises if any shard would exceed its per-shard capacity
    (a reshard is a no-eviction install — silently dropping rows would
    break replay equivalence).
    """
    S, n_slots = eng.num_shards, eng._n_slots
    fids = np.asarray(snap["fids"], np.int64)
    owners = flow_shard(fids, S) if len(fids) else np.zeros((0,), np.int64)
    counts = np.bincount(owners, minlength=S) if len(fids) else np.zeros(S, int)
    if (counts > eng.fcfg.capacity).any():
        worst = int(np.argmax(counts))
        raise ValueError(
            f"reshard to {S} shard(s) would put {int(counts[worst])} flows "
            f"on shard {worst} (> per-shard capacity {eng.fcfg.capacity}, "
            f"Eq. 11); raise capacity or evict before resharding"
        )
    eng.reset()
    s_idx = np.empty((len(fids),), np.intp)
    sl_idx = np.empty((len(fids),), np.intp)
    for i, (fid, own) in enumerate(zip(fids.tolist(), owners.tolist())):
        slot, fresh, evicted = eng.tables[own].slot_for(fid, tick)
        assert fresh and not evicted, (fid, own, slot)
        eng.tables[own].last_seen[slot] = int(snap["last_seen"][i])
        s_idx[i], sl_idx[i] = own, slot

    def scatter(rows, like):
        rows = np.asarray(rows)
        h = np.zeros((S, n_slots) + rows.shape[1:], like.dtype)
        h[s_idx, sl_idx] = rows
        return jax.device_put(jnp.asarray(h), eng._row_sharded)

    def scatter_cache(leaf, rows):
        rows = np.asarray(rows)
        if rows.ndim == 1 and rows.shape[0] == 0:
            return leaf  # non-slotted constant: keep the engine's copy
        h = np.zeros(leaf.shape, leaf.dtype)
        h[s_idx, :, sl_idx] = rows
        return jax.device_put(jnp.asarray(h), eng._row_sharded)

    new_state = (
        jax.tree_util.tree_map(scatter_cache, eng.caches, snap["caches"]),
        scatter(snap["positions"], eng.positions),
        scatter(snap["sig"], eng.sig),
        scatter(snap["hidden_sum"], eng.hidden_sum),
        scatter(snap["vetoed"], eng.vetoed),
    )
    old_state = (eng.caches, eng.positions, eng.sig, eng.hidden_sum, eng.vetoed)
    (eng.caches, eng.positions, eng.sig, eng.hidden_sum, eng.vetoed) = (
        atomic_swap(old_state, new_state)
    )
    eng._tick = tick


# --------------------------------------------------------------------------
# the service
# --------------------------------------------------------------------------

class ElasticFlowService:
    """Sharded flow serving with live resharding, shard fault tolerance and
    per-tenant admission control.  Satisfies the :class:`repro.serve.deploy
    .Engine` protocol — control-plane code written against the sharded
    engine works unchanged against the service."""

    def __init__(
        self,
        program,
        fcfg: FlowEngineConfig = FlowEngineConfig(),
        ecfg: ElasticConfig = ElasticConfig(),
        *,
        mesh=None,
        num_shards: Optional[int] = None,
        backend: Optional[str] = None,
    ):
        self.program = program
        self.ecfg = ecfg
        eng = build_sharded_engine(
            program, fcfg, mesh=mesh, num_shards=num_shards,
            backend=backend, record=False,
        )
        self.fcfg = eng.fcfg  # site config with resolved backend/horizon
        self._engines: Dict[int, ShardedFlowEngine] = {eng.num_shards: eng}
        self.engine = eng
        self.reshard_history: List[ReshardRecord] = []
        self._resharding = False

        # fault tolerance
        self._ckpt = (
            Checkpointer(ecfg.checkpoint_dir, keep=3)
            if ecfg.checkpoint_dir else None
        )
        self._ckpt_seq = 0
        self._last_ckpt: Optional[Tuple[Dict, Dict]] = None  # (snap, meta)
        self._replay: Deque[Tuple[int, np.ndarray, np.ndarray]] = (
            collections.deque(maxlen=max(1, ecfg.replay_window))
        )
        self.monitor = HeartbeatMonitor(timeout_s=ecfg.heartbeat_timeout_s)
        self._failed: set = set()

        # admission control
        self.tenants: Dict[str, TenantSpec] = {t.name: t for t in ecfg.tenants}
        self.tenants.setdefault(
            ecfg.default_tenant, TenantSpec(ecfg.default_tenant)
        )
        self._tenant_of: Dict[int, str] = {}
        self._tenant_count: Dict[str, int] = {}
        self.shed_packets: Dict[str, int] = {}
        self.shed_flows: Dict[str, int] = {}

        _reset_deploy_stages(program)
        program.ledger.entries.extend(eng._int_entries)
        record_sharding_entry(program, eng, note="elastic")
        self._record_admission_entries()
        program.ledger.raise_if_over()

    # ------------------------------------------------------------------
    # Engine-protocol passthroughs (the active topology's engine)
    # ------------------------------------------------------------------
    def __getattr__(self, name):
        # the rest of the read-only engine surface (backend, ccfg, params,
        # resident_state_bytes, ...) delegates to the ACTIVE topology, so
        # driver code written against the sharded engine runs unchanged
        if name.startswith("_") or name == "engine":
            raise AttributeError(name)
        return getattr(self.engine, name)

    @property
    def stats(self):
        return self.engine.stats

    @property
    def num_shards(self) -> int:
        return self.engine.num_shards

    @property
    def rules(self):
        return self.engine.rules

    @property
    def swap_history(self):
        return self.engine.swap_history

    @property
    def aggregate_capacity(self) -> int:
        return self.engine.aggregate_capacity

    @property
    def resident_flows(self) -> int:
        return self.engine.resident_flows

    def flow_ids(self) -> List[int]:
        return self.engine.flow_ids()

    def flow_scores(self, fid: int) -> Dict[str, float]:
        return self.engine.flow_scores(fid)

    def swap_tables(self, ruleset=None, weights=None, weight_spec=None,
                    delta=None):
        """Install new tables on the ACTIVE topology (measured, Eq. 18).
        Cached standby topologies get the current tables carried over
        inside the next reshard's measured install."""
        return self.engine.swap_tables(
            ruleset=ruleset, weights=weights, weight_spec=weight_spec,
            delta=delta,
        )

    def jit_entry_points(self) -> Dict[str, Any]:
        """Every cached topology's jitted entries, namespaced
        ``shards<N>.<name>`` — the retrace sentry audits them all, so a
        reshard that retraced steady-state ingest cannot hide."""
        entries: Dict[str, Any] = {}
        for S in sorted(self._engines):
            for name, fn in self._engines[S].jit_entry_points().items():
                entries[f"shards{S}.{name}"] = fn
        return entries

    # ------------------------------------------------------------------
    # ingest (admission control + replay buffer + heartbeats)
    # ------------------------------------------------------------------
    def ingest(self, flow_ids, tokens, tenant=None) -> Dict[str, np.ndarray]:
        """Same contract as :meth:`ShardedFlowEngine.ingest`, plus an
        ``admitted`` mask: packets of shed (not-admitted) new flows keep
        their output rows (trust 0, pred -1) but never reach the table.
        ``tenant`` is a name or a per-packet sequence of names; ``None``
        bills the default tenant."""
        if self._resharding:
            raise RuntimeError(
                "ingest during reshard quiesce — the migrating key ranges "
                "are frozen until the install commits or rolls back"
            )
        flow_ids = np.asarray(flow_ids)
        tokens = np.asarray(tokens, np.int32)
        admit = self._admit_mask(flow_ids, tenant)
        eng = self.engine
        if admit.all():
            out = eng.ingest(flow_ids, tokens)
        else:
            n = len(flow_ids)
            out = {
                "flow_ids": flow_ids,
                "trust": np.zeros((n,), np.float32),
                "vetoed": np.zeros((n,), bool),
                "pred": np.full((n,), -1, np.int32),
                "s_nn": np.zeros((n,), np.float32),
                "s_sym": np.zeros((n,), np.float32),
                "sig": np.zeros((n, eng.ccfg.sig_words), np.uint32),
            }
            if admit.any():
                sub = eng.ingest(flow_ids[admit], tokens[admit])
                for k in ("trust", "vetoed", "pred", "s_nn", "s_sym", "sig"):
                    out[k][admit] = sub[k]
            else:
                eng._tick += 1  # a shed-only batch still advances time
        out["admitted"] = admit
        if admit.any():
            self._replay.append(
                (eng._tick, flow_ids[admit].copy(), tokens[admit].copy())
            )
        for s in range(eng.num_shards):
            if s not in self._failed:
                self.monitor.beat(s, eng._tick)
        if (
            self.ecfg.checkpoint_every
            and eng._tick % self.ecfg.checkpoint_every == 0
        ):
            self.checkpoint()
        return out

    # ------------------------------------------------------------------
    # live resharding (Eq. 18-budgeted, rollback-capable)
    # ------------------------------------------------------------------
    def reshard(self, num_shards: int, *, reason: str = "scale") -> ReshardRecord:
        """Scale the flow table to ``num_shards`` shards without dropping a
        packet: quiesce → snapshot → re-route → measured install → commit
        (or roll back on an Eq. 18 ``t_cp`` violation)."""
        eng = self.engine
        old_S = eng.num_shards
        t_cp = self.fcfg.t_cp_s
        if num_shards == old_S:
            rec = ReshardRecord(
                tick=eng._tick, old_shards=old_S, new_shards=num_shards,
                reason=f"{reason} (no-op)", migrated_flows=0, moved_flows=0,
                install_s=0.0, t_cp_s=t_cp, churn_ok=True,
            )
            self.reshard_history.append(rec)
            return rec
        self._resharding = True  # quiesce: no ingest during the install
        try:
            fids = np.array(sorted(self._all_fids()), np.int64)
            moved = int(reshard_moves(fids, old_S, num_shards).sum())
            snap = snapshot_flow_state(eng)
            if self._ckpt is not None:
                # reshard snapshots ride the same checkpoint stream (they
                # are the freshest restore point a recovery could want)
                self._persist_snapshot(snap, kind=f"reshard->{num_shards}")
            target = self._engine_for(num_shards)

            def _install():
                self._carry_tables(eng, target)
                install_flow_state(target, snap, tick=eng._tick)
                return target.positions

            dt = measure_install_time(_install)
            ok = (
                hardware_model.install_time_ok(dt, t_cp) if t_cp else True
            )
            rec = ReshardRecord(
                tick=eng._tick, old_shards=old_S, new_shards=num_shards,
                reason=reason, migrated_flows=int(len(fids)),
                moved_flows=moved, install_s=dt, t_cp_s=t_cp, churn_ok=ok,
            )
            if ok:
                self._commit(target)
            else:
                rec.rolled_back = True
                rec.error = (
                    f"reshard install {dt:.6f}s exceeded t_cp {t_cp:.6f}s "
                    f"(Eq. 18); rolled back — old topology keeps serving"
                )
                target.reset()  # discard the provisional rows
        finally:
            self._resharding = False
        self.reshard_history.append(rec)
        return rec

    def _commit(self, target: ShardedFlowEngine) -> None:
        old = self.engine
        target._tick = old._tick
        target.stats = old.stats  # service-lifetime counters carry over
        self.engine = target
        record_sharding_entry(self.program, target, note="elastic")
        self._record_admission_entries()

    def _engine_for(self, num_shards: int) -> ShardedFlowEngine:
        eng = self._engines.get(num_shards)
        if eng is None:
            eng = build_sharded_engine(
                self.program, self.fcfg, num_shards=num_shards, record=False
            )
            if self.ecfg.keep_topologies:
                self._engines[num_shards] = eng
        return eng

    def _carry_tables(self, src: ShardedFlowEngine,
                      dst: ShardedFlowEngine) -> None:
        """Bring a (possibly stale) standby topology up to the active
        tables: replicate the current RuleSet onto the target mesh and
        requantize the int-emulation weight column.  Runs inside the
        measured install window — the Eq. 18 budget covers everything the
        reshard deploys."""
        dst.rules = atomic_swap(
            dst.rules, jax.device_put(src.rules, dst._replicated)
        )
        if dst._int_plan is not None:
            from repro.compile.int_lowering import requantize_rule_weights

            dst._int_tables = jax.device_put(
                {
                    **dst._int_tables,
                    "rule_w": requantize_rule_weights(
                        dst._int_plan, dst.rules.weights
                    ),
                },
                dst._replicated,
            )

    def _all_fids(self) -> List[int]:
        return self.engine.flow_ids()

    # ------------------------------------------------------------------
    # checkpoints + kill-a-shard recovery
    # ------------------------------------------------------------------
    def checkpoint(self) -> int:
        """Snapshot every resident flow's state (host + Checkpointer when a
        directory is configured).  Returns the checkpoint step id."""
        snap = snapshot_flow_state(self.engine)
        return self._persist_snapshot(snap, kind="periodic")

    def _persist_snapshot(self, snap: Dict, kind: str) -> int:
        meta = {
            "tick": int(self.engine._tick),
            "num_shards": int(self.engine.num_shards),
            "kind": kind,
            "tenant_of": {str(k): v for k, v in self._tenant_of.items()},
        }
        self._last_ckpt = (snap, meta)
        step = self._ckpt_seq
        if self._ckpt is not None:
            self._ckpt.save(step, snap, extra={"elastic": meta}, blocking=True)
        self._ckpt_seq += 1
        return step

    def restore_checkpoint(self, step: Optional[int] = None) -> int:
        """Load flow state from the checkpoint directory into the active
        topology (bit-exact round trip; composes with later
        ``swap_tables`` — rules are live state, not checkpoint state)."""
        if self._ckpt is None:
            raise RuntimeError(
                "no checkpoint directory configured "
                "(ElasticConfig.checkpoint_dir)"
            )
        snap, extra, step = self._ckpt.restore(
            snapshot_template(self.engine), step=step
        )
        meta = extra["elastic"]
        install_flow_state(self.engine, snap, tick=int(meta["tick"]))
        self._tenant_of = {
            int(k): v for k, v in meta.get("tenant_of", {}).items()
        }
        self._rebuild_tenant_counts()
        return step

    def kill_shard(self, shard: int) -> List[int]:
        """Chaos hook: simulate losing shard ``shard`` — its directory (and
        with it every resident flow it owned) is dropped and its heartbeat
        stops.  Returns the lost flow IDs."""
        eng = self.engine
        if not 0 <= shard < eng.num_shards:
            raise ValueError(f"no shard {shard} in a {eng.num_shards}-shard mesh")
        lost = sorted(eng.tables[shard].slot_of)
        eng.tables[shard].reset()
        self._failed.add(shard)
        return lost

    def dead_shards(self, now: Optional[float] = None) -> List[int]:
        """Shards whose heartbeat lapsed (HeartbeatMonitor view) merged
        with explicitly killed shards."""
        return sorted(set(self.monitor.dead_workers(now)) | self._failed)

    def recover(self, failed: Optional[Sequence[int]] = None, *,
                allow_partial: bool = False) -> ReshardRecord:
        """Kill-a-shard recovery: reshard the survivors' live rows onto the
        shrunk mesh, restore failed-shard flows from the last checkpoint,
        then replay the buffered post-checkpoint batches for exactly the
        lost key ranges (bounded by ``ElasticConfig.replay_window``).

        Raises unless the replay window reaches back to the checkpoint
        (data loss — pass ``allow_partial=True`` to accept the gap).  The
        install is measured like any reshard but commits even on an Eq. 18
        violation: a slow recovery beats serving with a dead shard, and the
        verdict is recorded for the operator.
        """
        eng = self.engine
        old_S = eng.num_shards
        failed_set = set(self._failed if failed is None else
                         (int(f) for f in np.atleast_1d(failed)))
        if not failed_set:
            raise ValueError("recover(): no failed shards")
        if self._last_ckpt is None and self._ckpt is None:
            raise RuntimeError(
                "recover(): no checkpoint to restore from — call "
                "checkpoint() (or set ElasticConfig.checkpoint_every)"
            )
        ck_snap, ck_meta = self._recovery_checkpoint()
        ck_tick = int(ck_meta["tick"])
        plan = plan_shard_recovery(old_S, sorted(failed_set), ck_tick)
        assert plan.valid, plan

        live = snapshot_flow_state(eng)  # killed directories are empty
        owners = flow_shard(ck_snap["fids"], old_S) if len(ck_snap["fids"]) \
            else np.zeros((0,), np.int64)
        lost_mask = np.isin(owners, np.asarray(sorted(failed_set)))
        restored = select_rows(ck_snap, lost_mask)
        merged = concat_snapshots(live, restored)

        # bounded-window coverage check BEFORE committing anything
        replayable = [b for b in self._replay if b[0] > ck_tick]
        window_start = min((b[0] for b in replayable), default=ck_tick + 1)
        gap = window_start > ck_tick + 1 and eng._tick > ck_tick
        if gap and len(self._replay) == self._replay.maxlen and not allow_partial:
            raise RuntimeError(
                f"recovery replay window ({self._replay.maxlen} batches) "
                f"does not reach back to checkpoint tick {ck_tick} "
                f"(earliest buffered tick {window_start}); lost flows would "
                f"come back stale — raise ElasticConfig.replay_window, "
                f"checkpoint more often, or pass allow_partial=True"
            )

        target = self._engine_for(plan.new_num_shards)

        def _install():
            self._carry_tables(eng, target)
            install_flow_state(target, merged, tick=eng._tick)
            return target.positions

        dt = measure_install_time(_install)
        t_cp = self.fcfg.t_cp_s
        ok = hardware_model.install_time_ok(dt, t_cp) if t_cp else True
        rec = ReshardRecord(
            tick=eng._tick, old_shards=old_S, new_shards=plan.new_num_shards,
            reason="recovery", migrated_flows=int(len(merged["fids"])),
            moved_flows=int(
                reshard_moves(merged["fids"], old_S, plan.new_num_shards).sum()
            ),
            install_s=dt, t_cp_s=t_cp, churn_ok=ok,
            failed_shards=plan.failed,
            restored_flows=int(lost_mask.sum()),
        )
        if not ok:
            rec.error = (
                f"recovery install {dt:.6f}s exceeded t_cp {t_cp:.6f}s "
                f"(Eq. 18); committed anyway — a dead shard is worse"
            )
        self._commit(target)
        self._failed.clear()
        # restore tenant billing for flows that only exist in the checkpoint
        ck_tenants = {
            int(k): v for k, v in ck_meta.get("tenant_of", {}).items()
        }
        for fid in restored["fids"].tolist():
            self._tenant_of.setdefault(fid, ck_tenants.get(
                fid, self.ecfg.default_tenant))
        self._rebuild_tenant_counts()

        # bounded replay: re-ingest post-checkpoint packets of LOST keys
        # only (survivors' rows are already current) through the new
        # topology, preserving the original batch order
        replayed = 0
        for btick, fids, toks in replayable:
            mask = np.isin(flow_shard(fids, old_S),
                           np.asarray(sorted(failed_set)))
            if mask.any():
                target.ingest(fids[mask], toks[mask])
                replayed += int(mask.sum())
        rec.replayed_packets = replayed
        self.reshard_history.append(rec)
        return rec

    def _recovery_checkpoint(self) -> Tuple[Dict, Dict]:
        if self._last_ckpt is not None:
            return self._last_ckpt
        snap, extra, _ = self._ckpt.restore(snapshot_template(self.engine))
        return snap, extra["elastic"]

    # ------------------------------------------------------------------
    # admission control (per-tenant budgets from the ResourceLedger)
    # ------------------------------------------------------------------
    def register_tenant(self, spec: TenantSpec) -> None:
        self.tenants[spec.name] = spec
        self._record_admission_entries()

    def tenant_budget_flows(self, name: str) -> int:
        """Tenant flow budget derived from the ledger's sharding entry:
        ``share × aggregate capacity``, additionally bounded by the share
        of the aggregate Eq. 11 byte budget."""
        t = self.tenants[name]
        eng = self.engine
        entry = next(
            (e for e in self.program.ledger.entries
             if e.stage == "flow-table-sharding"), None,
        )
        budget_bytes = (
            entry.budget * eng.num_shards if entry is not None
            else eng.aggregate_state_budget_bytes
        )
        by_flows = int(t.share * eng.aggregate_capacity)
        by_bytes = int(t.share * budget_bytes // eng.per_flow_state_bytes())
        return max(1, min(by_flows, by_bytes))

    def tenant_resident(self, name: str) -> int:
        return self._tenant_count.get(name, 0)

    def _record_admission_entries(self) -> None:
        ledger = self.program.ledger
        ledger.entries = [
            e for e in ledger.entries if e.stage != "admission-control"
        ]
        for t in sorted(self.tenants.values(),
                        key=lambda t: (-t.priority, t.name)):
            ledger.add(
                "admission-control", f"tenant[{t.name}]-flows",
                used=self.tenant_resident(t.name),
                budget=self.tenant_budget_flows(t.name),
                detail=(
                    f"priority {t.priority}, share {t.share:g} of "
                    f"{self.engine.aggregate_capacity}-flow aggregate; "
                    f"shed {self.shed_flows.get(t.name, 0)} flow(s) / "
                    f"{self.shed_packets.get(t.name, 0)} packet(s)"
                ),
            )

    def _rebuild_tenant_counts(self) -> None:
        resident = set(self.engine.flow_ids())
        self._tenant_of = {
            f: t for f, t in self._tenant_of.items() if f in resident
        }
        counts: Dict[str, int] = {}
        for t in self._tenant_of.values():
            counts[t] = counts.get(t, 0) + 1
        self._tenant_count = counts

    def _shed_victim(self, below_priority: int) -> Optional[int]:
        """Evict one resident flow of the lowest-priority tenant strictly
        below ``below_priority`` (deterministic: smallest fid).  Returns
        the evicted fid, or None when no lower-priority tenant has flows."""
        candidates = sorted(
            (t.priority, t.name) for t in self.tenants.values()
            if t.priority < below_priority and self._tenant_count.get(t.name, 0)
        )
        if not candidates:
            return None
        _, victim_tenant = candidates[0]
        fid = min(f for f, t in self._tenant_of.items() if t == victim_tenant)
        self.engine.evict(fid)
        del self._tenant_of[fid]
        self._tenant_count[victim_tenant] -= 1
        self.shed_flows[victim_tenant] = (
            self.shed_flows.get(victim_tenant, 0) + 1
        )
        return fid

    def _admit_mask(self, flow_ids: np.ndarray, tenant) -> np.ndarray:
        n = len(flow_ids)
        if tenant is None:
            names = [self.ecfg.default_tenant] * n
        elif isinstance(tenant, str):
            names = [tenant] * n
        else:
            names = [str(t) for t in tenant]
            if len(names) != n:
                raise ValueError(
                    f"per-packet tenant list has {len(names)} entries for "
                    f"{n} packets"
                )
        unknown = sorted(set(names) - set(self.tenants))
        if unknown:
            raise KeyError(
                f"unknown tenant(s) {unknown}; register a TenantSpec "
                f"(registered: {sorted(self.tenants)})"
            )
        self._rebuild_tenant_counts()
        eng = self.engine
        headroom = eng.aggregate_capacity - eng.resident_flows
        budgets = {nm: self.tenant_budget_flows(nm) for nm in set(names)}
        counts = dict(self._tenant_count)

        # one decision per NEW flow, highest-priority tenants first so the
        # lowest-priority tenants are the ones shed under pressure
        order = []
        seen = set()
        for i, (fid, nm) in enumerate(zip(flow_ids.tolist(), names)):
            if fid in self._tenant_of or fid in seen:
                continue
            seen.add(fid)
            order.append((-self.tenants[nm].priority, i, fid, nm))
        decided: Dict[int, bool] = {}
        for _, _, fid, nm in sorted(order):
            ok = counts.get(nm, 0) < budgets[nm] and headroom > 0
            if not ok and headroom <= 0 and counts.get(nm, 0) < budgets[nm]:
                # global pressure: shed a strictly lower-priority tenant's
                # flow to make room for this one
                if self._shed_victim(self.tenants[nm].priority) is not None:
                    headroom += 1
                    ok = True
            decided[fid] = ok
            if ok:
                counts[nm] = counts.get(nm, 0) + 1
                headroom -= 1
                self._tenant_of[fid] = nm
                self._tenant_count[nm] = self._tenant_count.get(nm, 0) + 1
            else:
                # a shed NEW flow may retry next batch — count the shed
                # attempt now, packets below
                self.shed_flows[nm] = self.shed_flows.get(nm, 0) + 1
        admit = np.ones((n,), bool)
        for i, (fid, nm) in enumerate(zip(flow_ids.tolist(), names)):
            if not decided.get(fid, True):
                admit[i] = False
                self.shed_packets[nm] = self.shed_packets.get(nm, 0) + 1
        return admit
