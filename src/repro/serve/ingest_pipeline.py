"""Async host-side ingest pipeline over a fused FlowEngine (DESIGN.md §15).

The fused ``flow_ingest`` path splits an ingest call into two halves with
very different hardware owners:

  host   — directory lookup, LRU/idle eviction, arrival-round packing into
           the pinned staging buffers (``FlowEngine._dispatch_fused``),
  device — the single-launch fused step per width group.

Run synchronously, the host half and the device half serialize.  This
pipeline overlaps them with a ring of ``depth`` staging slots: ``submit``
packs batch k+1 into slot (k+1) % depth and dispatches it while the device
is still chewing on batch k — JAX's async dispatch returns before the
computation completes, and each ring slot owns a private host buffer pool,
so packing never races the in-flight transfer sourced from another slot.

Ordering and state are untouched: slot resolution happens in ``submit`` in
arrival order (the flow directory is host state, mutated synchronously),
and the device launches are enqueued in order on one stream, so the fused
path remains bit-identical to synchronous ingest.  The ring only bounds
how far the *host* runs ahead; ``submit`` applies backpressure by
finalizing the batch that last used the slot it is about to reuse.

    pipe = AsyncIngestPipeline(engine)         # engine built with fused=True
    for batch in scenario:
        pipe.submit(batch["flow_ids"], batch["tokens"])
    results = pipe.drain()                     # per-batch output dicts

``ingest(...)`` is a drop-in synchronous wrapper (submit + finalize) for
call sites that need each batch's outputs immediately but still want the
pre-packed staging path.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


class AsyncIngestPipeline:
    """Ring-buffered double-ended ingest: host packs ahead, device drains."""

    def __init__(self, engine, depth: Optional[int] = None):
        if getattr(engine, "_jit_fused", None) is None:
            raise ValueError(
                "AsyncIngestPipeline requires a fused engine "
                "(FlowEngineConfig(fused=True))"
            )
        self.engine = engine
        self.depth = depth or engine.fcfg.ring_slots
        if self.depth < 1:
            raise ValueError(f"ring depth must be >= 1, got {self.depth}")
        # one private staging-buffer pool per ring slot (allocated lazily by
        # _dispatch_fused and reused across batches — pinned host memory in
        # the ring-DMA sense: stable buffers the transfers source from)
        self._pools: List[Dict] = [{} for _ in range(self.depth)]
        self._pending: List[Optional[object]] = [None] * self.depth
        self._seq = 0  # batches submitted
        self._results: List[Dict[str, np.ndarray]] = []

    @property
    def in_flight(self) -> int:
        return sum(p is not None for p in self._pending)

    def submit(self, flow_ids, tokens) -> None:
        """Pack and dispatch one batch; returns without blocking on device
        results (beyond ring backpressure)."""
        eng = self.engine
        flow_ids = np.asarray(flow_ids)
        tokens = np.asarray(tokens, np.int32)
        P, _ = tokens.shape
        assert flow_ids.shape == (P,), (flow_ids.shape, P)

        slot = self._seq % self.depth
        prev = self._pending[slot]
        if prev is not None:
            # ring full for this slot: harvest before reusing its buffers
            self._results.append(prev.finalize())
            self._pending[slot] = None

        slots, fresh = eng._resolve_slots(flow_ids)
        self._pending[slot] = eng._dispatch_fused(
            flow_ids, tokens, slots, fresh, staging=self._pools[slot]
        )
        self._seq += 1

    def poll(self) -> List[Dict[str, np.ndarray]]:
        """Harvest every completed/ordered result accumulated so far."""
        out, self._results = self._results, []
        return out

    def drain(self) -> List[Dict[str, np.ndarray]]:
        """Finalize all in-flight batches; returns results in submit order."""
        for k in range(max(self._seq - self.depth, 0), self._seq):
            slot = k % self.depth
            p = self._pending[slot]
            if p is not None:
                self._results.append(p.finalize())
                self._pending[slot] = None
        return self.poll()

    def ingest(self, flow_ids, tokens) -> Dict[str, np.ndarray]:
        """Synchronous drop-in for ``engine.ingest`` through the ring path."""
        self.submit(flow_ids, tokens)
        slot = (self._seq - 1) % self.depth
        res = self._pending[slot].finalize()
        self._pending[slot] = None
        return res
